//! Hermetic, deterministic serving tests — the multi-worker multi-tenant
//! server exercised end to end (quantize → pack → serve) under plain
//! `cargo test -q`: no `artifacts/` (the models come from
//! `svdquant::fixture`), no wall-clock sleeps (traces replay on a virtual
//! clock, so multi-minute arrival spans complete in milliseconds of real
//! time).
//!
//! Concurrency assertions are interleaving-invariant: conservation
//! (`completions + shed + expired == trace.len()`), uniqueness of
//! completed request ids, single-tenant batches, batch-size bounds — true
//! under every legal schedule, so the suite is deterministic at any
//! `SVDQUANT_THREADS` setting (CI runs 1 and 4).

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use svdquant::coordinator::server::{
    serve, serve_trace, BatchMode, BoundedQueue, Enqueue, Registry, SchedPolicy, ServerConfig,
    ServiceModel,
};
use svdquant::data::{TaggedRequest, TraceGenerator};
use svdquant::fixture;
use svdquant::util::clock::Clock;
use svdquant::util::histogram::Histogram;
use svdquant::util::proptest::{check, Shrink};

/// Honor the CI thread matrix: `SVDQUANT_THREADS` caps the kernel pool the
/// same way `--threads` does (1 = fully-serial reentrancy path, 4 =
/// pool-parallel path). Idempotent, so concurrent tests don't race.
fn init_threads() {
    if let Ok(v) = std::env::var("SVDQUANT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            svdquant::util::pool::set_global_parallelism(n);
        }
    }
}

#[test]
fn quantize_pack_serve_virtual_time_multi_tenant() {
    init_threads();
    let cfg = fixture::tiny_config();
    // two tenants: independently quantized models over distinct datasets
    let (qm_a, ds_a) = fixture::deployed_fixture(&cfg, 1, 8, 10).unwrap();
    let (qm_b, ds_b) = fixture::deployed_fixture(&cfg, 2, 8, 14).unwrap();
    let mut reg = Registry::new();
    reg.add("alpha", &qm_a, &ds_a);
    reg.add("beta", &qm_b, &ds_b);

    // a bursty trace spanning ~2 virtual minutes
    let trace =
        TraceGenerator::bursty(5.0, 0.2, 6).generate_tagged(600, &reg.sample_counts(), 0x5EED);
    let span = trace.last().unwrap().arrival_s;
    assert!(span > 30.0, "trace should span tens of virtual seconds, got {span}");

    let scfg = ServerConfig { workers: 2, clock: Clock::virt(), ..Default::default() };
    let t0 = Instant::now();
    let stats = serve(&reg, &trace, &scfg).unwrap();
    let real_s = t0.elapsed().as_secs_f64();
    assert!(
        real_s < 2.0,
        "a {span:.0}s virtual trace must replay in well under a second of real \
         time, took {real_s:.3}s"
    );

    // conservation: every request accounted for exactly once
    assert_eq!(stats.completions + stats.shed + stats.expired, trace.len());
    assert_eq!(stats.offered, trace.len(), "no chaos storms: offered == trace");
    assert_eq!(stats.expired, 0, "no deadline configured");
    assert!(stats.completions > 0, "some requests must complete");
    assert_eq!(stats.clamped, 0, "healthy run must not reject latency samples");
    assert_eq!(stats.slo_attainment, 1.0, "no SLOs configured: attainment is trivial");

    // no request lost or duplicated across the worker pool
    assert_eq!(stats.completions_log.len(), stats.completions, "log covers this trace");
    let ids: HashSet<usize> = stats.completions_log.iter().map(|c| c.id).collect();
    assert_eq!(ids.len(), stats.completions, "duplicate completion ids");
    assert!(ids.iter().all(|&i| i < trace.len()));

    // per-tenant stats partition the totals
    assert_eq!(stats.per_tenant.len(), 2);
    assert_eq!(stats.per_tenant[0].task, "alpha");
    assert_eq!(stats.per_tenant[1].task, "beta");
    assert_eq!(stats.per_tenant.iter().map(|t| t.completions).sum::<usize>(), stats.completions);
    assert_eq!(stats.per_tenant.iter().map(|t| t.shed).sum::<usize>(), stats.shed);
    for t in &stats.per_tenant {
        assert!(t.completions > 0, "tenant {} starved", t.task);
        assert!((0.0..=1.0).contains(&t.accuracy));
    }

    // batches: bounded, and every sample within its tenant's dataset
    for c in &stats.completions_log {
        assert!(c.batch_size >= 1 && c.batch_size <= scfg.max_batch);
        let bound = if c.task == 0 { ds_a.len() } else { ds_b.len() };
        assert!(c.sample < bound, "cross-tenant sample index");
    }

    // virtual elapsed covers at least the arrival span
    assert!(stats.wall_s >= span - 1e-6);
    assert!((0.0..=1.0).contains(&stats.accuracy));
}

#[test]
fn completion_latency_components_sum_to_total() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 3, 4, 8).unwrap();
    let trace = TraceGenerator::poisson(50.0).generate(200, ds.len(), 0xABCD);
    let scfg = ServerConfig { workers: 2, clock: Clock::virt(), ..Default::default() };
    let stats = serve_trace(&qm, &ds, &trace, &scfg).unwrap();
    assert!(stats.completions > 0);
    for c in &stats.completions_log {
        assert!(c.queue_ms >= 0.0, "queue_ms {}", c.queue_ms);
        assert!(c.batch_ms >= 0.0, "batch_ms {}", c.batch_ms);
        assert!(c.exec_ms >= 0.0, "exec_ms {}", c.exec_ms);
        let sum = c.queue_ms + c.batch_ms + c.exec_ms;
        assert!(
            (sum - c.total_ms).abs() < 1e-6,
            "components {sum} must sum to total {}",
            c.total_ms
        );
    }
}

#[test]
fn deadline_and_shed_accounting_stays_conserved() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 4, 4, 8).unwrap();
    // tiny queue + tight deadline under a flooding virtual-time replay:
    // admission control and expiry both get exercised; whatever the
    // interleaving, the books must balance
    let trace = TraceGenerator::bursty(200.0, 0.3, 12).generate(500, ds.len(), 0xF00D);
    let scfg = ServerConfig {
        queue_cap: 8,
        workers: 2,
        deadline: Some(Duration::from_millis(1)),
        clock: Clock::virt(),
        ..Default::default()
    };
    let stats = serve_trace(&qm, &ds, &trace, &scfg).unwrap();
    assert_eq!(stats.completions + stats.shed + stats.expired, trace.len());
    assert_eq!(stats.per_tenant.iter().map(|t| t.expired).sum::<usize>(), stats.expired);
    assert_eq!(stats.per_tenant.iter().map(|t| t.shed).sum::<usize>(), stats.shed);
    // ids of completed requests are still unique
    let ids: HashSet<usize> = stats.completions_log.iter().map(|c| c.id).collect();
    assert_eq!(ids.len(), stats.completions_log.len());
}

#[test]
fn serve_handles_empty_trace_and_rejects_unknown_tasks() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 5, 4, 6).unwrap();
    let reg = Registry::single("only", &qm, &ds);
    let scfg = ServerConfig { workers: 2, clock: Clock::virt(), ..Default::default() };
    // empty trace: graceful close, zero stats, no hang
    let stats = serve(&reg, &[], &scfg).unwrap();
    assert_eq!(stats.completions + stats.shed + stats.expired, 0);
    // a request tagged for an unregistered tenant is an error, not a hang
    let bad = [TaggedRequest { id: 0, task: 7, arrival_s: 0.0, sample: 0, len_bucket: 0 }];
    assert!(serve(&reg, &bad, &scfg).is_err());
}

#[test]
fn queue_stress_no_request_lost_or_duplicated() {
    init_threads();
    let clock = Clock::virt();
    let queue = Arc::new(BoundedQueue::new(4096, clock.clone()));
    let n_producers = 4usize;
    let per = 250usize;
    let n = n_producers * per;
    let consumed: Mutex<Vec<usize>> = Mutex::new(Vec::with_capacity(n));

    std::thread::scope(|scope| {
        let producers: Vec<_> = (0..n_producers)
            .map(|p| {
                let q = Arc::clone(&queue);
                scope.spawn(move || {
                    for i in 0..per {
                        let id = p * per + i;
                        let r = TaggedRequest {
                            id,
                            task: id % 3,
                            arrival_s: 0.0,
                            sample: 0,
                            len_bucket: 0,
                        };
                        // cap 4096 ≥ n: nothing may shed in this test
                        assert_eq!(q.push(r), Enqueue::Accepted);
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&queue);
                let consumed = &consumed;
                scope.spawn(move || loop {
                    let batch = q.pop_batch(8, Duration::from_millis(1));
                    if batch.is_empty() {
                        return; // closed and drained — exactly-once exit
                    }
                    assert!(batch.len() <= 8, "batch exceeds max_batch");
                    let task = batch[0].req.task;
                    assert!(
                        batch.iter().all(|it| it.req.task == task),
                        "mixed-tenant batch"
                    );
                    consumed.lock().unwrap().extend(batch.iter().map(|it| it.req.id));
                })
            })
            .collect();
        for h in producers {
            h.join().unwrap();
        }
        queue.close();
        for h in consumers {
            h.join().unwrap();
        }
    });

    assert_eq!(queue.shed_count(), 0);
    assert!(queue.is_empty(), "close must drain completely");
    let mut ids = consumed.into_inner().unwrap();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "every id exactly once");
}

/// Property-test input for the size-or-deadline batcher: a pre-filled
/// queue (tenant per item), a batch cap, and a straggler budget.
#[derive(Debug)]
struct PopCase {
    tasks: Vec<usize>,
    max_batch: usize,
    wait_ms: u64,
}

impl Shrink for PopCase {
    fn shrink(&self) -> Vec<Self> {
        if self.tasks.len() <= 1 {
            return Vec::new();
        }
        let half = self.tasks.len() / 2;
        vec![
            PopCase {
                tasks: self.tasks[..half].to_vec(),
                max_batch: self.max_batch,
                wait_ms: self.wait_ms,
            },
            PopCase {
                tasks: self.tasks[half..].to_vec(),
                max_batch: self.max_batch,
                wait_ms: self.wait_ms,
            },
        ]
    }
}

#[test]
fn pop_batch_size_or_deadline_property() {
    init_threads();
    check(
        "pop_batch size-or-deadline on the virtual clock",
        |rng| PopCase {
            tasks: (0..rng.range(1, 40)).map(|_| rng.range(0, 3)).collect(),
            max_batch: rng.range(1, 16),
            wait_ms: rng.range(1, 50) as u64,
        },
        |case| {
            let clock = Clock::virt();
            let q = BoundedQueue::new(4096, clock.clone());
            for (i, &task) in case.tasks.iter().enumerate() {
                let r = TaggedRequest { id: i, task, arrival_s: 0.0, sample: 0, len_bucket: 0 };
                if q.push(r) != Enqueue::Accepted {
                    return Err("push refused below capacity".into());
                }
            }
            let head = case.tasks[0];
            let same_head = case.tasks.iter().filter(|&&t| t == head).count();
            let t0 = clock.now_s();
            let batch = q.pop_batch(case.max_batch, Duration::from_millis(case.wait_ms));
            let t1 = clock.now_s();

            // the batch is the FIFO prefix of the head's tenant, capped
            let expect = same_head.min(case.max_batch);
            if batch.len() != expect {
                return Err(format!("batch len {} expected {expect}", batch.len()));
            }
            if batch.iter().any(|it| it.req.task != head) {
                return Err("batch must be single-tenant (head's tenant)".into());
            }
            let got_ids: Vec<usize> = batch.iter().map(|it| it.req.id).collect();
            let want_ids: Vec<usize> = (0..case.tasks.len())
                .filter(|&i| case.tasks[i] == head)
                .take(expect)
                .collect();
            if got_ids != want_ids {
                return Err(format!("FIFO order violated: {got_ids:?} vs {want_ids:?}"));
            }

            if same_head >= case.max_batch {
                // size-triggered: no straggler wait, the clock is untouched
                if t1 != t0 {
                    return Err(format!("size-full batch advanced the clock by {}", t1 - t0));
                }
            } else {
                // deadline-triggered: the batcher advanced exactly max_wait
                let want = case.wait_ms as f64 * 1e-3;
                if ((t1 - t0) - want).abs() > 1e-6 {
                    return Err(format!("deadline batch advanced {} not {want}", t1 - t0));
                }
            }
            // other tenants keep their queue positions
            if q.len() != case.tasks.len() - expect {
                return Err(format!(
                    "queue kept {} items, expected {}",
                    q.len(),
                    case.tasks.len() - expect
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn continuous_batching_end_to_end_conserves_and_refills() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 7, 4, 8).unwrap();
    let reg = Registry::single("only", &qm, &ds);
    // a flooding virtual-time replay against one worker: the backlog
    // runs hundreds deep, so refill pops find queued work essentially
    // every iteration (the counter assertion below needs just one)
    let trace =
        TraceGenerator::bursty(300.0, 0.25, 8).generate_tagged(600, &reg.sample_counts(), 0xC0B1);
    let scfg = ServerConfig {
        workers: 1,
        queue_cap: 2048,
        batching: BatchMode::Continuous,
        service: Some(ServiceModel { base_s: 2e-3, per_req_s: 5e-4, simulate: true }),
        clock: Clock::virt(),
        ..Default::default()
    };
    let stats = serve(&reg, &trace, &scfg).unwrap();

    // the invariants that must survive the batching-mode change:
    // conservation, batch bounds, and exactly-once completion ids
    assert_eq!(stats.completions + stats.shed + stats.expired, trace.len());
    assert_eq!(stats.shed, 0, "capacity covers the whole trace");
    assert_eq!(stats.completions, trace.len());
    let ids: HashSet<usize> = stats.completions_log.iter().map(|c| c.id).collect();
    assert_eq!(ids.len(), stats.completions_log.len(), "duplicate completion ids");
    for c in &stats.completions_log {
        assert!(c.batch_size >= 1 && c.batch_size <= scfg.max_batch);
    }
    // the refill path demonstrably ran (the counter only materializes
    // in the exposition once a worker increments it)
    assert!(
        stats.metrics_text.contains("serve_refilled_batches_total"),
        "continuous mode must refill at least once against a deep backlog:\n{}",
        stats.metrics_text
    );
    // deep-backlog drains should reach full batches routinely
    assert!(
        stats.mean_batch > 1.5,
        "refill against a deep backlog should batch well, got {}",
        stats.mean_batch
    );
}

/// Property-test input for `pop_refill`: a pre-filled queue of
/// (tenant, length-bucket) keyed items, a worker affinity hint, a batch
/// cap, and the scheduling policy.
#[derive(Debug)]
struct RefillCase {
    items: Vec<(usize, u8)>,
    hint: Option<(usize, u8)>,
    max_batch: usize,
    edf: bool,
}

impl Shrink for RefillCase {
    fn shrink(&self) -> Vec<Self> {
        if self.items.len() <= 1 {
            return Vec::new();
        }
        let half = self.items.len() / 2;
        vec![
            RefillCase {
                items: self.items[..half].to_vec(),
                hint: self.hint,
                max_batch: self.max_batch,
                edf: self.edf,
            },
            RefillCase {
                items: self.items[half..].to_vec(),
                hint: self.hint,
                max_batch: self.max_batch,
                edf: self.edf,
            },
        ]
    }
}

#[test]
fn pop_refill_bucket_purity_and_policy_heads_property() {
    init_threads();
    // per-tenant SLOs (seconds) for the EDF cases; distinct so the EDF
    // head is unambiguous, with strictly increasing arrivals so the
    // first item of any key holds that key's minimum deadline
    const SLO_S: [f64; 3] = [0.30, 0.20, 0.10];
    check(
        "pop_refill: single-key batches, cap respected, policy head preserved",
        |rng| RefillCase {
            items: (0..rng.range(1, 40))
                .map(|_| (rng.range(0, 3), rng.range(0, 3) as u8))
                .collect(),
            hint: if rng.chance(0.7) {
                Some((rng.range(0, 3), rng.range(0, 3) as u8))
            } else {
                None
            },
            max_batch: rng.range(1, 16),
            edf: rng.chance(0.5),
        },
        |case| {
            let clock = Clock::virt();
            let (policy, slo_s) = if case.edf {
                (SchedPolicy::Edf, SLO_S.iter().map(|&s| Some(s)).collect())
            } else {
                (SchedPolicy::Fifo, Vec::new())
            };
            let q = BoundedQueue::with_policy(4096, clock, policy, slo_s);
            for (i, &(task, bucket)) in case.items.iter().enumerate() {
                let r = TaggedRequest {
                    id: i,
                    task,
                    arrival_s: i as f64 * 0.01,
                    sample: 0,
                    len_bucket: bucket,
                };
                if q.push(r) != Enqueue::Accepted {
                    return Err("push refused below capacity".into());
                }
            }

            let batch = q.pop_refill(case.hint, case.max_batch);
            if batch.is_empty() {
                return Err("refill from a non-empty queue must return items".into());
            }
            if batch.len() > case.max_batch {
                return Err(format!("batch {} exceeds cap {}", batch.len(), case.max_batch));
            }
            // bucket purity: one (task, len_bucket) key per batch
            let key = (batch[0].req.task, batch[0].req.len_bucket);
            if batch.iter().any(|it| (it.req.task, it.req.len_bucket) != key) {
                return Err(format!("mixed-key batch under key {key:?}"));
            }
            // FIFO prefix of the key: the first `len` queued ids of it
            let got: Vec<usize> = batch.iter().map(|it| it.req.id).collect();
            let want: Vec<usize> = (0..case.items.len())
                .filter(|&i| (case.items[i].0, case.items[i].1) == key)
                .take(batch.len())
                .collect();
            if got != want {
                return Err(format!("not the key's FIFO prefix: {got:?} vs {want:?}"));
            }

            if case.edf {
                // the queue-wide minimum-deadline request anchors every
                // refilled batch — the hint must never override urgency
                let anchor = (0..case.items.len())
                    .min_by(|&a, &b| {
                        let da = a as f64 * 0.01 + SLO_S[case.items[a].0];
                        let db = b as f64 * 0.01 + SLO_S[case.items[b].0];
                        da.total_cmp(&db)
                    })
                    .unwrap();
                if batch[0].req.id != anchor {
                    return Err(format!(
                        "EDF head {anchor} missing from refill (got head {})",
                        batch[0].req.id
                    ));
                }
            } else {
                // FIFO honors the affinity hint when the hinted key has
                // queued work, and falls back to the queue head otherwise
                let hinted = case
                    .hint
                    .filter(|h| case.items.iter().any(|&(t, b)| (t, b) == *h));
                let expect_key = hinted.unwrap_or(case.items[0]);
                if key != expect_key {
                    return Err(format!("FIFO key {key:?}, expected {expect_key:?}"));
                }
            }
            // everything else keeps its queue position
            if q.len() != case.items.len() - batch.len() {
                return Err(format!(
                    "queue kept {} items, expected {}",
                    q.len(),
                    case.items.len() - batch.len()
                ));
            }
            Ok(())
        },
    );
}

/// Deterministic single-threaded drive of one serving loop over a
/// bursty trace: admits arrivals the timeline has passed, pops with the
/// given batching mode, expires overdue requests, and spends a modeled
/// service cost in virtual time. Returns (completions per pop,
/// completions, expired).
fn drive_batching(continuous: bool, trace: &[TaggedRequest]) -> (f64, usize, usize) {
    let clock = Clock::virt();
    let q = BoundedQueue::new(4096, clock.clone());
    let max_batch = 8usize;
    let max_wait = Duration::from_millis(60);
    let deadline_s = 0.100;
    let (base_s, per_req_s) = (3e-3, 1.5e-3);
    let mut i = 0usize;
    let mut refill_key: Option<(usize, u8)> = None;
    let (mut pops, mut completions, mut expired) = (0usize, 0usize, 0usize);
    loop {
        // admit every arrival the virtual timeline has already passed
        while i < trace.len() && trace[i].arrival_s <= clock.now_s() {
            assert_eq!(q.push(trace[i]), Enqueue::Accepted);
            i += 1;
        }
        if q.is_empty() {
            if i >= trace.len() {
                break;
            }
            clock.sleep_until(trace[i].arrival_s);
            continue;
        }
        let batch = if continuous {
            let b = q.pop_refill(refill_key, max_batch);
            if b.is_empty() { q.pop_batch(max_batch, max_wait) } else { b }
        } else {
            // the fixed window burns `max_wait` of virtual time whenever
            // the batch comes up partial — aging the whole backlog
            q.pop_batch(max_batch, max_wait)
        };
        assert!(!batch.is_empty());
        refill_key = Some((batch[0].req.task, batch[0].req.len_bucket));
        pops += 1;
        let now = clock.now_s();
        let live = batch.iter().filter(|it| now - it.req.arrival_s <= deadline_s).count();
        expired += batch.len() - live;
        completions += live;
        if live > 0 {
            clock.sleep_until(now + base_s + per_req_s * live as f64);
        }
    }
    (completions as f64 / pops.max(1) as f64, completions, expired)
}

#[test]
fn continuous_refill_beats_fixed_windows_under_deadline_rot() {
    init_threads();
    // 2 tenants x 3 length buckets = 6 batch keys: per-key depth stays
    // below `max_batch`, so the fixed window's straggler wait fires on
    // nearly every pop. Each 60ms burn advances the timeline against a
    // 100ms deadline — the backlog ages, expiries gut the batches, and
    // occupancy collapses. Continuous refill pops instantly, so virtual
    // time only advances with arrivals and service cost.
    //
    // Single-threaded and virtually clocked, so the comparison is
    // bit-deterministic: both modes see the identical trace.
    let trace = TraceGenerator::bursty(300.0, 0.2, 8)
        .with_seq_buckets(&[0.5, 0.3, 0.2])
        .generate_tagged(400, &[10, 10], 0x0CCA);
    let (fixed_occ, fixed_done, fixed_expired) = drive_batching(false, &trace);
    let (cont_occ, cont_done, cont_expired) = drive_batching(true, &trace);

    // every request is accounted in both modes
    assert_eq!(fixed_done + fixed_expired, trace.len());
    assert_eq!(cont_done + cont_expired, trace.len());
    // the rot must actually bite the baseline, or this test shows nothing
    assert!(
        fixed_expired > trace.len() / 10,
        "fixed windows should expire heavily under rot, got {fixed_expired}"
    );
    assert!(
        cont_done > fixed_done,
        "continuous completions {cont_done} must beat fixed {fixed_done}"
    );
    assert!(
        cont_expired < fixed_expired,
        "continuous expiries {cont_expired} must undercut fixed {fixed_expired}"
    );
    // the acceptance bar: delivered batch occupancy (completions per
    // pop) above the fixed-window baseline on the same bursty trace
    assert!(
        cont_occ > fixed_occ,
        "continuous occupancy {cont_occ:.2} must beat fixed {fixed_occ:.2}"
    );
}

#[test]
fn serve_percentiles_match_exact_sorted_within_one_bucket() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 6, 4, 8).unwrap();
    // short virtual span so every latency stays inside the histogram
    // range, where the one-bucket agreement contract applies
    let trace = TraceGenerator::poisson(1000.0).generate(300, ds.len(), 0xBEEF);
    let scfg = ServerConfig { workers: 2, clock: Clock::virt(), ..Default::default() };
    let stats = serve_trace(&qm, &ds, &trace, &scfg).unwrap();
    assert_eq!(stats.completions_log.len(), stats.completions);
    assert!(stats.completions > 0);

    let mut lat: Vec<f64> = stats.completions_log.iter().map(|c| c.total_ms).collect();
    lat.sort_by(|a, b| a.total_cmp(b));
    let hist_default = Histogram::latency_ms();
    let w = hist_default.width_ms();
    assert!(
        *lat.last().unwrap() < w * 8192.0,
        "latencies must stay in histogram range for this test"
    );
    for (p, got) in [(0.50, stats.p50_ms), (0.95, stats.p95_ms), (0.99, stats.p99_ms)] {
        let exact = lat[((lat.len() as f64 * p) as usize).min(lat.len() - 1)];
        assert!(
            (got - exact).abs() <= w,
            "p{p}: histogram {got} vs exact {exact} (width {w})"
        );
    }
}
