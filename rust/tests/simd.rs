//! Cross-ISA parity suite for the runtime-dispatched kernels
//! (`svdquant::util::simd`, DESIGN.md §8).
//!
//! The contract under test is *bitwise identity*: every dispatch arm
//! (AVX2 / SSE4.1 / scalar) of `dot_i8`, the activation quantizer, and
//! the BitPack decode must produce byte-for-byte the same outputs, across
//! widths 2/3/4/8, odd/even lengths, and every tail remainder 0..=31 —
//! plus an end-to-end `matmul_xt_int` case with the dispatch toggled,
//! the in-process equivalent of rerunning under `SVDQUANT_NO_SIMD=1`
//! (CI runs the whole suite both ways for the env-var path itself).
//!
//! Tests that flip the process-wide dispatch serialize on [`ISA_LOCK`] so
//! a concurrently running override cannot *mask* an arm (identity itself
//! is unaffected — that is the point of the contract — but a test that
//! believes it pinned AVX2 while another pinned scalar would silently
//! stop covering the wide arm).

use std::sync::Mutex;

use svdquant::linalg::Matrix;
use svdquant::quant::packing::BitPack;
use svdquant::quant::{quantize_rows, QuantConfig, QuantizedMatrix, SUPPORTED_BITS};
use svdquant::sparse::Coo;
use svdquant::util::rng::Rng;
use svdquant::util::simd::{
    dot_i8_on, override_isa, quantize_row_on, supported_isas, unpack4_into_on, Isa,
};

static ISA_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    ISA_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Lengths covering empty, sub-vector, exact-vector, and every tail
/// remainder 0..=31 past a full 64-element body.
fn tail_lengths() -> Vec<usize> {
    let mut lens = vec![0, 1, 2, 3, 5, 8, 15, 16, 17, 31, 32, 33, 63];
    lens.extend((0..=31).map(|rem| 64 + rem));
    lens.push(1024);
    lens.push(1031);
    lens
}

#[test]
fn dot_i8_bitwise_identical_across_arms() {
    let mut rng = Rng::new(0xD07);
    for len in tail_lengths() {
        let a: Vec<i8> = (0..len).map(|_| rng.range(0, 256) as u8 as i8).collect();
        let b: Vec<i8> = (0..len).map(|_| rng.range(0, 256) as u8 as i8).collect();
        let want = dot_i8_on(Isa::Scalar, &a, &b, len);
        // exact i32 reference, independently computed
        let check: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(want, check, "scalar arm vs naive reference, len {len}");
        for isa in supported_isas() {
            assert_eq!(dot_i8_on(isa, &a, &b, len), want, "{isa:?} len {len}");
        }
    }
}

#[test]
fn quantize_bitwise_identical_across_arms() {
    let mut rng = Rng::new(0xD08);
    for len in tail_lengths() {
        let row: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 2.5)).collect();
        let mut want = vec![0i8; len];
        let s_want = quantize_row_on(Isa::Scalar, &row, &mut want);
        for isa in supported_isas() {
            let mut got = vec![0i8; len];
            let s_got = quantize_row_on(isa, &row, &mut got);
            assert_eq!(s_got, s_want, "{isa:?} len {len}: scale");
            assert_eq!(got, want, "{isa:?} len {len}: codes");
        }
    }
}

#[test]
fn quantize_rounds_ties_to_even() {
    // amax = 127 makes the scale exactly 1, so inputs are the pre-round
    // values; every arm must land .5 ties on the even neighbor
    let row = [127.0f32, 0.5, -0.5, 1.5, 2.5, 3.5, -1.5, -2.5, -3.5, 126.5];
    let want = [127i8, 0, 0, 2, 2, 4, -2, -2, -4, 126];
    for isa in supported_isas() {
        let mut got = [0i8; 10];
        let s = quantize_row_on(isa, &row, &mut got);
        assert_eq!(s, 1.0, "{isa:?}: scale");
        assert_eq!(got, want, "{isa:?}: ties-even codes");
    }
}

#[test]
fn bitpack_decode_bitwise_identical_across_arms_and_widths() {
    let mut rng = Rng::new(0xD09);
    for bits in SUPPORTED_BITS {
        let codec = BitPack::new(bits).unwrap();
        let span = (codec.code_max() as i32 - codec.code_min() as i32 + 1) as usize;
        for n in tail_lengths() {
            let codes: Vec<i8> = (0..n)
                .map(|_| (codec.code_min() as i32 + rng.range(0, span) as i32) as i8)
                .collect();
            let packed = codec.pack(&codes);
            // the serial bit-walk is the ground truth for the stream layout
            let mut want = vec![0i8; n];
            codec.unpack_into_serial(&packed, &mut want);
            assert_eq!(want, codes, "b={bits} n={n}: serial roundtrip");
            if bits == 4 {
                // the SIMD nibble expand, pinned per arm explicitly
                for isa in supported_isas() {
                    let mut got = vec![0i8; n];
                    unpack4_into_on(isa, &packed, &mut got);
                    assert_eq!(got, want, "{isa:?} b=4 n={n}");
                }
            }
            // the dispatched decode under each installed override
            let _guard = lock();
            for isa in supported_isas() {
                let _g = override_isa(isa);
                let mut got = vec![0i8; n];
                codec.unpack_into(&packed, &mut got);
                assert_eq!(got, want, "{isa:?} b={bits} n={n} dispatched");
            }
        }
    }
}

#[test]
fn matmul_xt_int_bitwise_identical_with_dispatch_toggled() {
    // end to end: quantize a matrix at every width (salient overlay
    // included), then run the full integer forward under scalar-forced
    // dispatch and under every hardware arm — outputs must be equal to
    // the last bit, which is exactly what makes `SVDQUANT_NO_SIMD=1` a
    // pure perf switch
    let _guard = lock();
    let mut rng = Rng::new(0xD0A);
    for bits in SUPPORTED_BITS {
        let (rows, cols, batch) = (19, 173, 5);
        let mut w = Matrix::zeros(rows, cols);
        rng.fill_normal(w.data_mut(), 0.05);
        let mut sal = Coo::new(rows, cols);
        for idx in rng.sample_distinct(rows * cols, 60) {
            sal.push(idx / cols, idx % cols, w[(idx / cols, idx % cols)]);
        }
        let cfg = QuantConfig::default().with_bits(bits);
        let qm = QuantizedMatrix::from_dense(&w, &cfg, &sal);
        let mut x = Matrix::zeros(batch, cols);
        rng.fill_normal(x.data_mut(), 1.0);

        let want = {
            let _g = override_isa(Isa::Scalar);
            qm.matmul_xt_int(&x)
        };
        for isa in supported_isas() {
            let _g = override_isa(isa);
            let got = qm.matmul_xt_int(&x);
            assert!(got.approx_eq(&want, 0.0), "{isa:?} bits {bits}: forward diverged");
            // and the float reference path, which also decodes through
            // the dispatched codec at 4 bits
            let fref = {
                let _s = override_isa(Isa::Scalar);
                qm.matmul_xt(&x)
            };
            let fgot = qm.matmul_xt(&x);
            assert!(fgot.approx_eq(&fref, 0.0), "{isa:?} bits {bits}: float path diverged");
        }
    }
}

#[test]
fn activation_batch_quantize_identical_across_arms() {
    let _guard = lock();
    let mut rng = Rng::new(0xD0B);
    let mut x = Matrix::zeros(9, 201);
    rng.fill_normal(x.data_mut(), 1.7);
    let want = {
        let _g = override_isa(Isa::Scalar);
        quantize_rows(&x)
    };
    for isa in supported_isas() {
        let _g = override_isa(isa);
        let got = quantize_rows(&x);
        assert_eq!(got.codes, want.codes, "{isa:?}: batch codes");
        assert_eq!(got.scales, want.scales, "{isa:?}: batch scales");
    }
}
