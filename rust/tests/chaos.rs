//! Chaos + SLO suite: the serving stack under scripted failure injection
//! and deadline pressure, hermetic on the virtual clock (no sleeps, no
//! artifacts — models come from `svdquant::fixture`, multi-minute traces
//! replay in milliseconds of real time).
//!
//! The load-bearing assertion everywhere is request conservation,
//! `completions + shed + expired == offered` where
//! `offered = trace.len() + storm-injected`. `serve` *enforces* it with a
//! descriptive error, so most tests only need `serve(..).unwrap()` plus
//! checks that the chaos actually happened (kills consumed, storms shed,
//! backlogs expired) — a vacuous pass is impossible.

use std::collections::HashSet;
use std::time::{Duration, Instant};

use svdquant::coordinator::server::{
    serve, ChaosPlan, Registry, SchedPolicy, ServeStats, ServerConfig, ServiceModel,
};
use svdquant::data::TraceGenerator;
use svdquant::fixture;
use svdquant::util::clock::Clock;
use svdquant::util::proptest::{check, Shrink};

/// Honor the CI thread matrix (same contract as `serving.rs`).
fn init_threads() {
    if let Ok(v) = std::env::var("SVDQUANT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            svdquant::util::pool::set_global_parallelism(n);
        }
    }
}

/// Sanity bundle shared by every scenario: totals partition per tenant and
/// completed ids are unique and within the offered id space.
fn assert_books_balance(stats: &ServeStats, offered: usize) {
    assert_eq!(stats.offered, offered);
    assert_eq!(stats.completions + stats.shed + stats.expired, offered);
    assert_eq!(
        stats.per_tenant.iter().map(|t| t.completions).sum::<usize>(),
        stats.completions
    );
    assert_eq!(stats.per_tenant.iter().map(|t| t.shed).sum::<usize>(), stats.shed);
    assert_eq!(stats.per_tenant.iter().map(|t| t.expired).sum::<usize>(), stats.expired);
    let ids: HashSet<usize> = stats.completions_log.iter().map(|c| c.id).collect();
    assert_eq!(ids.len(), stats.completions_log.len(), "duplicate completion ids");
    assert!(ids.iter().all(|&i| i < offered), "completion id outside the offered space");
    assert_eq!(stats.clamped, 0, "latency samples must never be negative/non-finite");
}

#[test]
fn kill_and_respawn_mid_drain_conserves_every_request() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm_a, ds_a) = fixture::deployed_fixture(&cfg, 11, 8, 10).unwrap();
    let (qm_b, ds_b) = fixture::deployed_fixture(&cfg, 12, 8, 14).unwrap();
    let mut reg = Registry::new();
    reg.add("alpha", &qm_a, &ds_a);
    reg.add("beta", &qm_b, &ds_b);

    let trace =
        TraceGenerator::bursty(10.0, 0.2, 6).generate_tagged(600, &reg.sample_counts(), 0xC1A0);
    let span = trace.last().unwrap().arrival_s;
    // real forward passes (no service model): the kill lands on a worker
    // that is genuinely executing batches, not a pure simulation
    let scfg = ServerConfig {
        workers: 2,
        queue_cap: 4096,
        clock: Clock::virt(),
        chaos: Some(ChaosPlan::new().kill_at(span * 0.25).respawn_at(span * 0.30)),
        ..Default::default()
    };
    let t0 = Instant::now();
    let stats = serve(&reg, &trace, &scfg).unwrap();
    assert!(
        t0.elapsed().as_secs_f64() < 5.0,
        "chaos scenario must stay hermetic-fast on the virtual clock"
    );

    assert_books_balance(&stats, trace.len());
    assert_eq!(stats.worker_kills, 1, "the kill token must be consumed");
    assert_eq!(stats.worker_respawns, 1);
    assert_eq!(stats.injected, 0);
    assert_eq!(stats.expired, 0, "no deadline and a surviving pool: nothing expires");
    assert_eq!(stats.completions, trace.len(), "cap is large: nothing sheds either");
}

#[test]
fn killing_every_worker_strands_then_expires_the_backlog() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 13, 4, 8).unwrap();
    let mut reg = Registry::new();
    reg.add("solo", &qm, &ds);

    let trace =
        TraceGenerator::poisson(20.0).generate_tagged(600, &reg.sample_counts(), 0xDEAD);
    let span = trace.last().unwrap().arrival_s;
    let scfg = ServerConfig {
        workers: 1,
        queue_cap: 4096,
        clock: Clock::virt(),
        service: Some(ServiceModel::simulated(0.002, 0.001)),
        chaos: Some(ChaosPlan::new().kill_at(span * 0.25)),
        ..Default::default()
    };
    let stats = serve(&reg, &trace, &scfg).unwrap();

    assert_books_balance(&stats, trace.len());
    assert_eq!(stats.worker_kills, 1);
    assert_eq!(stats.worker_respawns, 0);
    // everything offered after the lone worker died can only leave through
    // the post-drain sweep — and its queue waits must be visible
    assert!(stats.expired > 200, "most of the trace strands: got {}", stats.expired);
    assert!(stats.expired_wait_p50_ms > 0.0);
    assert!(stats.expired_wait_p99_ms >= stats.expired_wait_p50_ms);
    assert!(stats.expired_wait_max_ms >= stats.expired_wait_p99_ms - 1e-9);
    assert_eq!(stats.per_tenant[0].expired, stats.expired);
    assert!(stats.per_tenant[0].expired_wait_p99_ms > 0.0);
}

#[test]
fn queue_storm_sheds_the_overflow_and_still_balances() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 14, 4, 8).unwrap();
    let mut reg = Registry::new();
    reg.add("solo", &qm, &ds);

    let trace =
        TraceGenerator::poisson(50.0).generate_tagged(300, &reg.sample_counts(), 0x5707);
    let span = trace.last().unwrap().arrival_s;
    let scfg = ServerConfig {
        workers: 2,
        queue_cap: 32,
        clock: Clock::virt(),
        service: Some(ServiceModel::simulated(0.002, 0.001)),
        chaos: Some(ChaosPlan::new().storm_at(span * 0.5, 1000, 0)),
        ..Default::default()
    };
    let stats = serve(&reg, &trace, &scfg).unwrap();

    let offered = trace.len() + 1000;
    assert_books_balance(&stats, offered);
    assert_eq!(stats.injected, 1000);
    // 1000 requests hit a 32-slot queue in one instant: the vast majority
    // must shed (workers can drain at most a few batches mid-storm)
    assert!(stats.shed > 200, "storm must overwhelm admission: shed {}", stats.shed);
    assert!(stats.completions > 0);
}

#[test]
fn edf_beats_fifo_on_a_bursty_zipf_trace() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 15, 4, 16).unwrap();
    // one tight-SLO interactive tenant (the Zipf head, most traffic) and
    // one best-effort bulk tenant sharing the same deployed model
    let mut reg = Registry::new();
    reg.add_with_slo("tight", &qm, &ds, Some(Duration::from_millis(100)));
    reg.add("bulk", &qm, &ds);

    // modeled capacity: cost(16) = 4 + 2·16 = 36ms → ~444 req/s on one
    // worker; offered ~400 req/s (0.9× capacity) with bursts, so FIFO
    // backlogs regularly push the tight tenant past its 100ms SLO while
    // EDF keeps pulling the earliest deadline to the head
    let service = ServiceModel::simulated(0.004, 0.002);
    let trace = TraceGenerator::bursty(400.0, 0.2, 8)
        .with_zipf(1.2)
        .generate_tagged(4000, &reg.sample_counts(), 0xED9);

    let run = |sched: SchedPolicy| {
        let scfg = ServerConfig {
            workers: 1,
            queue_cap: 8192,
            sched,
            service: Some(service),
            clock: Clock::virt(),
            ..Default::default()
        };
        serve(&reg, &trace, &scfg).unwrap()
    };
    let fifo = run(SchedPolicy::Fifo);
    let edf = run(SchedPolicy::Edf);
    assert_books_balance(&fifo, trace.len());
    assert_books_balance(&edf, trace.len());

    assert!(
        fifo.slo_attainment < 0.95,
        "the trace must actually stress FIFO (attainment {:.3})",
        fifo.slo_attainment
    );
    assert!(
        edf.slo_attainment > fifo.slo_attainment + 0.05,
        "EDF must measurably beat FIFO: edf {:.3} vs fifo {:.3}",
        edf.slo_attainment,
        fifo.slo_attainment
    );
    // the win comes from the SLO'd tenant, not from starving accounting
    assert!(
        edf.per_tenant[0].slo_attainment > fifo.per_tenant[0].slo_attainment,
        "tight tenant: edf {:.3} vs fifo {:.3}",
        edf.per_tenant[0].slo_attainment,
        fifo.per_tenant[0].slo_attainment
    );
    assert_eq!(edf.per_tenant[1].slo_attainment, 1.0, "no SLO → trivially attained");
}

#[test]
fn expired_waits_land_in_dedicated_histograms() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 16, 4, 8).unwrap();
    let mut reg = Registry::new();
    reg.add("solo", &qm, &ds);

    // offered 2× modeled capacity with a 50ms budget: the backlog grows
    // without bound, so a large fraction expires at batch time — and those
    // waits must be observable, not silently dropped (they are the worst
    // tail of the system)
    let service = ServiceModel::simulated(0.004, 0.002);
    let trace =
        TraceGenerator::poisson(900.0).generate_tagged(3000, &reg.sample_counts(), 0xE19E);
    let scfg = ServerConfig {
        workers: 1,
        queue_cap: 8192,
        deadline: Some(Duration::from_millis(50)),
        service: Some(service),
        clock: Clock::virt(),
        ..Default::default()
    };
    let stats = serve(&reg, &trace, &scfg).unwrap();

    assert_books_balance(&stats, trace.len());
    assert!(stats.expired > 500, "2x overload must expire heavily: {}", stats.expired);
    // expired waits exceed the 50ms budget by construction
    assert!(stats.expired_wait_p50_ms > 50.0);
    assert!(stats.expired_wait_p99_ms >= stats.expired_wait_p50_ms);
    // the completion histogram cannot see these waits: the live p99 stays
    // bounded near the deadline while the expired tail keeps growing
    assert!(stats.expired_wait_p99_ms > stats.p99_ms);
}

/// A random chaos scenario: trace shape, server shape, and a scripted
/// plan, all drawn from ranges that cover under- and over-load.
#[derive(Debug, Clone)]
struct ChaosCase {
    n: usize,
    rate: f64,
    workers: usize,
    queue_cap: usize,
    deadline_ms: Option<u64>,
    edf: bool,
    events: Vec<(u8, f64, usize)>, // (kind, time fraction of span, storm n)
}

impl ChaosCase {
    fn plan(&self, span: f64) -> ChaosPlan {
        let mut plan = ChaosPlan::new();
        for &(kind, frac, storm_n) in &self.events {
            let at = span * frac;
            plan = match kind % 3 {
                0 => plan.kill_at(at),
                1 => plan.respawn_at(at),
                _ => plan.storm_at(at, storm_n.max(1), 0),
            };
        }
        plan
    }
}

impl Shrink for ChaosCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.n > 20 {
            out.push(ChaosCase { n: self.n / 2, ..self.clone() });
        }
        for i in 0..self.events.len() {
            let mut c = self.clone();
            c.events.remove(i);
            out.push(c);
        }
        if self.workers > 1 {
            out.push(ChaosCase { workers: 1, ..self.clone() });
        }
        out
    }
}

#[test]
fn conservation_holds_under_every_random_chaos_scenario() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm_a, ds_a) = fixture::deployed_fixture(&cfg, 17, 4, 8).unwrap();
    let (qm_b, ds_b) = fixture::deployed_fixture(&cfg, 18, 4, 12).unwrap();
    let mut reg = Registry::new();
    reg.add_with_slo("a", &qm_a, &ds_a, Some(Duration::from_millis(80)));
    reg.add("b", &qm_b, &ds_b);

    check(
        "completions + shed + expired == offered under random chaos",
        |rng| ChaosCase {
            n: rng.range(50, 400),
            rate: rng.uniform(50.0, 500.0),
            workers: rng.range(1, 4),
            queue_cap: [8, 64, 4096][rng.range(0, 3)],
            deadline_ms: rng.chance(0.5).then(|| rng.range(10, 100) as u64),
            edf: rng.chance(0.5),
            events: (0..rng.range(0, 5))
                .map(|_| (rng.range(0, 3) as u8, rng.f64(), rng.range(1, 200)))
                .collect(),
        },
        |case| {
            let trace = TraceGenerator::bursty(case.rate, 0.2, 6)
                .with_zipf(1.1)
                .generate_tagged(case.n, &reg.sample_counts(), 0xCA05);
            let span = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);
            let plan = case.plan(span);
            let offered = case.n + plan.injected();
            let scfg = ServerConfig {
                workers: case.workers,
                queue_cap: case.queue_cap,
                deadline: case.deadline_ms.map(Duration::from_millis),
                sched: if case.edf { SchedPolicy::Edf } else { SchedPolicy::Fifo },
                service: Some(ServiceModel::simulated(0.002, 0.001)),
                chaos: Some(plan),
                clock: Clock::virt(),
                ..Default::default()
            };
            // serve() itself enforces conservation and shed-tally agreement
            // in every build; an Err here IS the property failing
            let stats = serve(&reg, &trace, &scfg).map_err(|e| format!("{e:#}"))?;
            if stats.offered != offered {
                return Err(format!("offered {} != {offered}", stats.offered));
            }
            if stats.completions + stats.shed + stats.expired != offered {
                return Err("books do not balance".into());
            }
            let per: usize = stats.per_tenant.iter().map(|t| t.completions + t.shed + t.expired).sum();
            if per != offered {
                return Err(format!("per-tenant partition {per} != {offered}"));
            }
            let ids: HashSet<usize> =
                stats.completions_log.iter().map(|c| c.id).collect();
            if ids.len() != stats.completions_log.len() {
                return Err("duplicate completion ids".into());
            }
            if stats.clamped != 0 {
                return Err(format!("{} clamped latency samples", stats.clamped));
            }
            Ok(())
        },
    );
}
