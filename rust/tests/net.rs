//! Hermetic network tests for the socket front door (DESIGN.md §12):
//! every test binds `127.0.0.1:0` (an ephemeral loopback port), drives
//! the server over a real TCP connection, and stops it with either a
//! settle target ([`NetConfig::stop_after`]) or a [`StopHandle`] — no
//! fixed ports, no sleeps, no external processes, and the whole suite
//! holds to the repo's wall-time budget under plain `cargo test -q`.
//!
//! The concurrency assertions are interleaving-invariant, mirroring
//! `rust/tests/serving.rs`: the conservation law (`completions + shed +
//! expired == offered`), exactly-one-response-per-request, bounded
//! write buffers under a slow reader, and no worker hangs after a
//! client vanishes mid-request — true under every legal schedule.
//!
//! The frame decoder is additionally property-tested: decoding is
//! invariant under arbitrary byte-split chunkings, and malformed or
//! oversize frames produce protocol errors — never a panic, and never
//! a queue permit (parse rejects stay outside the conservation law,
//! which the mixed valid/garbage end-to-end test pins).
#![cfg(unix)]

use std::collections::HashSet;
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use svdquant::coordinator::server::net::proto::{
    self, encode_request, read_response, FrameDecoder, FrameError, WireRequest, WireStatus,
    REQ_BODY_LEN, RESP_BODY_LEN, WIRE_VERSION,
};
use svdquant::coordinator::server::{
    BatchMode, ChaosPlan, NetConfig, NetServer, Registry, ServerConfig, ServiceModel,
};
use svdquant::fixture;
use svdquant::util::clock::Clock;
use svdquant::util::proptest::{check, Shrink};

/// Honor the CI thread matrix (see `rust/tests/serving.rs`).
fn init_threads() {
    if let Ok(v) = std::env::var("SVDQUANT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            svdquant::util::pool::set_global_parallelism(n);
        }
    }
}

/// A valid request frame for tenant `task`, sample `sample`. The
/// arrival stamp is 1ns — an explicit virtual-clock replay stamp, so
/// admission timing is independent of when the reactor decodes it.
fn wire_req(task: u16, sample: u32, corr: u32) -> WireRequest {
    WireRequest { task, sample, len_bucket: 0, arrival_ns: 1, corr }
}

/// Connect to `addr` with a failsafe read timeout: a server bug makes a
/// test *fail* on the timeout instead of hanging the suite.
fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let sock = TcpStream::connect(addr).expect("connecting to loopback server");
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    sock
}

#[test]
fn pipelined_requests_on_one_connection_all_answer_and_conserve() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm_a, ds_a) = fixture::deployed_fixture(&cfg, 21, 8, 10).unwrap();
    let (qm_b, ds_b) = fixture::deployed_fixture(&cfg, 22, 8, 12).unwrap();
    let mut reg = Registry::new();
    reg.add("alpha", &qm_a, &ds_a);
    reg.add("beta", &qm_b, &ds_b);

    let n = 60u32;
    let srv = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { stop_after: Some(n as usize), ..NetConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr().unwrap();
    let scfg = ServerConfig {
        workers: 2,
        clock: Clock::virt(),
        batching: BatchMode::Continuous,
        ..Default::default()
    };

    let t0 = Instant::now();
    let (stats, resps) = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        // pipeline everything in one write: the reactor must decode and
        // admit frames back-to-back off a single connection
        let mut wire = Vec::new();
        for i in 0..n {
            let (task, samples) = if i % 2 == 0 { (0u16, 10u32) } else { (1u16, 12u32) };
            wire.extend(encode_request(&wire_req(task, i % samples, 1000 + i)));
        }
        sock.write_all(&wire).unwrap();
        let resps: Vec<_> =
            (0..n).map(|_| read_response(&mut sock).expect("response")).collect();
        (server.join().expect("server thread").unwrap(), resps)
    });
    assert!(t0.elapsed().as_secs_f64() < 5.0, "hermetic suite must stay fast");

    // exactly one response per correlation id, every one completed
    let corrs: HashSet<u32> = resps.iter().map(|r| r.corr).collect();
    assert_eq!(corrs.len(), n as usize, "duplicate or missing correlation ids");
    assert!(corrs.iter().all(|c| (1000..1000 + n).contains(c)));
    assert!(resps.iter().all(|r| r.status == WireStatus::Ok), "all must complete: {resps:?}");
    assert!(resps.iter().all(|r| r.pred >= 0), "real forward pass returns an argmax");

    // the same books as the in-process replay, fed from the wire
    assert_eq!(stats.offered, n as usize);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);
    assert_eq!(stats.completions, n as usize);
    let net = stats.net.expect("socket ingress reports wire counters");
    assert_eq!(net.connections, 1);
    assert_eq!(net.frames_in, n as u64);
    assert_eq!(net.frames_out, n as u64);
    assert_eq!(net.parse_errors, 0);
    assert_eq!(net.refused_closed, 0);
    assert_eq!(net.responses_dropped, 0);
    assert_eq!(net.bytes_in, n as u64 * (4 + REQ_BODY_LEN) as u64);
    assert_eq!(net.bytes_out, n as u64 * (4 + RESP_BODY_LEN) as u64);
    // wire metrics surface in the exposition (deterministic families only)
    assert!(stats.metrics_text.contains("serve_net_frames_in_total"));
    assert!(!stats.metrics_text.contains("high_water"), "flush-timing metric must stay out");
}

#[test]
fn slow_reader_backpressure_keeps_write_buffers_bounded() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 23, 8, 10).unwrap();
    let reg = Registry::single("only", &qm, &ds);

    let n = 300u32;
    let write_buf_cap = 256usize;
    let max_inflight = 8usize;
    let srv = NetServer::bind(
        "127.0.0.1:0",
        NetConfig {
            write_buf_cap,
            max_inflight_per_conn: max_inflight,
            stop_after: Some(n as usize),
            ..NetConfig::default()
        },
    )
    .unwrap();
    let addr = srv.local_addr().unwrap();
    let scfg = ServerConfig {
        workers: 2,
        clock: Clock::virt(),
        batching: BatchMode::Continuous,
        service: Some(ServiceModel { base_s: 1e-4, per_req_s: 1e-5, simulate: true }),
        ..Default::default()
    };

    let (stats, resps) = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        // fire the whole burst before reading a single response: the
        // server may only buffer what the read gates admit
        let mut wire = Vec::new();
        for i in 0..n {
            wire.extend(encode_request(&wire_req(0, i % 10, i)));
        }
        sock.write_all(&wire).unwrap();
        let resps: Vec<_> =
            (0..n).map(|_| read_response(&mut sock).expect("response")).collect();
        (server.join().expect("server thread").unwrap(), resps)
    });

    assert_eq!(resps.len(), n as usize);
    assert!(resps.iter().all(|r| r.status == WireStatus::Ok));
    let corrs: HashSet<u32> = resps.iter().map(|r| r.corr).collect();
    assert_eq!(corrs.len(), n as usize);
    assert_eq!(stats.completions, n as usize);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);

    // the backpressure bound: unsent responses never exceed the cap plus
    // one frame per admitted-but-unanswered request (outcomes already
    // owed are delivered regardless — refusing them would deadlock)
    let net = stats.net.unwrap();
    let frame = 4 + RESP_BODY_LEN;
    assert!(
        net.write_buf_high_water <= write_buf_cap + (max_inflight + 1) * frame,
        "write buffer grew past the backpressure bound: {} > {} + {}",
        net.write_buf_high_water,
        write_buf_cap,
        (max_inflight + 1) * frame
    );
    assert_eq!(net.frames_out, n as u64);
    assert_eq!(net.responses_dropped, 0);
}

#[test]
fn client_disconnect_mid_request_leaves_no_hang_and_balanced_books() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 24, 8, 10).unwrap();
    let reg = Registry::single("only", &qm, &ds);

    let k = 12u32;
    let srv = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { stop_after: Some(k as usize), ..NetConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr().unwrap();
    let scfg = ServerConfig {
        workers: 2,
        clock: Clock::virt(),
        service: Some(ServiceModel { base_s: 1e-4, per_req_s: 1e-5, simulate: true }),
        ..Default::default()
    };

    let t0 = Instant::now();
    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        let mut wire = Vec::new();
        for i in 0..k {
            wire.extend(encode_request(&wire_req(0, i % 10, i)));
        }
        // a torn 13th frame, then vanish without reading anything
        wire.extend(&encode_request(&wire_req(0, 0, 999))[..10]);
        sock.write_all(&wire).unwrap();
        drop(sock);
        server.join().expect("server thread").unwrap()
    });
    assert!(t0.elapsed().as_secs_f64() < 5.0, "disconnect must not hang the serve");

    // all admitted work completes and the books balance even though the
    // replies had nowhere to go; the torn frame never became a request
    assert_eq!(stats.offered, k as usize);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);
    assert_eq!(stats.completions, k as usize);
    let net = stats.net.unwrap();
    assert_eq!(net.frames_in, k as u64, "the partial frame must not decode");
    assert_eq!(net.parse_errors, 0);
    assert_eq!(net.connections, 1);
}

#[test]
fn deadline_expiry_answers_on_the_wire() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 25, 8, 10).unwrap();
    let reg = Registry::single("only", &qm, &ds);

    // a zero deadline with a straggler window: every popped request has
    // aged past its (zero) budget by pop time, so all of them expire —
    // deterministically, because the batcher's max_wait burn advances
    // the virtual clock past the 1ns arrival stamps
    let n = 8u32;
    let srv = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { stop_after: Some(n as usize), ..NetConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr().unwrap();
    let scfg = ServerConfig {
        workers: 1,
        clock: Clock::virt(),
        deadline: Some(Duration::ZERO),
        ..Default::default()
    };

    let (stats, resps) = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        let mut wire = Vec::new();
        for i in 0..n {
            wire.extend(encode_request(&wire_req(0, i % 10, i)));
        }
        sock.write_all(&wire).unwrap();
        let resps: Vec<_> =
            (0..n).map(|_| read_response(&mut sock).expect("response")).collect();
        (server.join().expect("server thread").unwrap(), resps)
    });

    assert!(resps.iter().all(|r| r.status == WireStatus::Expired), "{resps:?}");
    assert!(resps.iter().all(|r| r.pred == -1));
    assert!(resps.iter().all(|r| r.lat_us > 0), "expiries report their queue wait");
    assert_eq!(stats.expired, n as usize);
    assert_eq!(stats.completions, 0);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);
}

#[test]
fn shed_and_strand_sweep_answer_on_the_wire() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 26, 8, 10).unwrap();
    let reg = Registry::single("only", &qm, &ds);

    // kill the only worker before the first arrival: nothing ever
    // drains, so the tiny queue fills (at most cap, +1 for the dying
    // worker's pop-and-redeliver window) and every later push sheds.
    // After the explicit stop, the strand sweep must answer the
    // accepted-but-stranded requests as Expired — a client never hangs
    // on a request the server has given up on.
    let n = 40u32;
    let queue_cap = 4usize;
    let srv = NetServer::bind("127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = srv.local_addr().unwrap();
    let stop = srv.stop_handle();
    let scfg = ServerConfig {
        workers: 1,
        queue_cap,
        max_batch: 1,
        chaos: Some(ChaosPlan::parse("kill@0").unwrap()),
        clock: Clock::virt(),
        ..Default::default()
    };

    let (stats, front, swept) = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        let mut wire = Vec::new();
        for i in 0..n {
            wire.extend(encode_request(&wire_req(0, i % 10, i)));
        }
        sock.write_all(&wire).unwrap();
        // per-connection responses are FIFO, and with the worker dead
        // the last request is guaranteed to shed — so reading up to its
        // correlation id collects exactly the front-door verdicts
        let mut front = Vec::new();
        loop {
            let r = read_response(&mut sock).expect("front-door verdict");
            let last = r.corr == n - 1;
            front.push(r);
            if last {
                break;
            }
        }
        stop.stop();
        // everything still unanswered is stranded in the queue; the
        // sweep owes each one an Expired response before shutdown
        let swept: Vec<_> = (front.len()..n as usize)
            .map(|_| read_response(&mut sock).expect("strand-sweep response"))
            .collect();
        (server.join().expect("server thread").unwrap(), front, swept)
    });

    assert!(front.iter().all(|r| r.status == WireStatus::Shed), "{front:?}");
    assert!(swept.iter().all(|r| r.status == WireStatus::Expired), "{swept:?}");
    // cap or cap+1 requests were admitted (the dying worker may briefly
    // pop one before redelivering), the rest shed
    assert!(
        (queue_cap..=queue_cap + 1).contains(&swept.len()),
        "expected ~queue_cap stranded requests, got {}",
        swept.len()
    );
    assert_eq!(stats.worker_kills, 1);
    assert_eq!(stats.completions, 0);
    assert_eq!(stats.shed, front.len());
    assert_eq!(stats.expired, swept.len());
    assert_eq!(stats.offered, n as usize);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);
    // every correlation id answered exactly once across both phases
    let corrs: HashSet<u32> =
        front.iter().chain(&swept).map(|r| r.corr).collect();
    assert_eq!(corrs.len(), n as usize);
}

#[test]
fn malformed_frames_answer_error_and_never_take_a_queue_permit() {
    init_threads();
    let cfg = fixture::tiny_config();
    let (qm, ds) = fixture::deployed_fixture(&cfg, 27, 8, 10).unwrap();
    let reg = Registry::single("only", &qm, &ds);

    let valid = 10u32;
    let srv = NetServer::bind(
        "127.0.0.1:0",
        NetConfig { stop_after: Some(valid as usize), ..NetConfig::default() },
    )
    .unwrap();
    let addr = srv.local_addr().unwrap();
    let scfg = ServerConfig {
        workers: 2,
        clock: Clock::virt(),
        service: Some(ServiceModel { base_s: 1e-4, per_req_s: 1e-5, simulate: true }),
        ..Default::default()
    };

    let (stats, resps) = std::thread::scope(|s| {
        let server = s.spawn(|| srv.serve(&reg, &scfg));
        let mut sock = connect(addr);
        // interleave valid frames with three kinds of garbage: a bad
        // version byte (unrecoverable corr → echoed as 0), an unknown
        // tenant, and an out-of-range sample index
        let mut wire = Vec::new();
        let mut junk = 0u32;
        for i in 0..valid {
            wire.extend(encode_request(&wire_req(0, i % 10, i)));
            match i % 3 {
                0 => {
                    let mut bad = encode_request(&wire_req(0, 0, 7000 + i));
                    bad[4] = WIRE_VERSION + 9;
                    wire.extend(bad);
                }
                1 => wire.extend(encode_request(&wire_req(9, 0, 7000 + i))),
                _ => wire.extend(encode_request(&wire_req(0, 10_000, 7000 + i))),
            }
            junk += 1;
        }
        sock.write_all(&wire).unwrap();
        let resps: Vec<_> = (0..valid + junk)
            .map(|_| read_response(&mut sock).expect("response"))
            .collect();
        (server.join().expect("server thread").unwrap(), resps)
    });

    let oks: Vec<_> = resps.iter().filter(|r| r.status == WireStatus::Ok).collect();
    let errs: Vec<_> = resps.iter().filter(|r| r.status == WireStatus::Error).collect();
    assert_eq!(oks.len(), valid as usize, "{resps:?}");
    assert_eq!(errs.len(), valid as usize, "one error verdict per garbage frame");
    let ok_corrs: HashSet<u32> = oks.iter().map(|r| r.corr).collect();
    assert_eq!(ok_corrs, (0..valid).collect::<HashSet<_>>());

    // the conservation law covers exactly the valid requests: garbage
    // was refused at the door and never took a queue permit
    assert_eq!(stats.offered, valid as usize);
    assert_eq!(stats.completions, valid as usize);
    assert_eq!(stats.completions + stats.shed + stats.expired, stats.offered);
    let net = stats.net.unwrap();
    assert_eq!(net.parse_errors, valid as u64);
    assert_eq!(net.frames_in, (valid * 2) as u64, "well-framed garbage still counts as a frame");
}

// ---------------------------------------------------------------------------
// decoder properties: chunking invariance and malformed-stream safety
// ---------------------------------------------------------------------------

/// One decode outcome, normalized for comparison across chunkings.
type Outcome = Result<WireRequest, FrameError>;

/// Pull every decodable frame, stopping after a fatal error (which is
/// sticky by contract). Returns true when the stream turned fatal.
fn drain_outcomes(d: &mut FrameDecoder, out: &mut Vec<Outcome>) -> bool {
    loop {
        match d.next_frame() {
            None => return false,
            Some(Ok(r)) => out.push(Ok(r)),
            Some(Err(e @ FrameError::Frame { .. })) => out.push(Err(e)),
            Some(Err(e @ FrameError::Fatal(_))) => {
                out.push(Err(e));
                return true;
            }
        }
    }
}

/// A byte stream assembled from well-formed, malformed, and garbage
/// segments, plus the chunk sizes it will be fed in.
#[derive(Debug)]
struct StreamCase {
    bytes: Vec<u8>,
    chunks: Vec<usize>,
    max_frame: usize,
}

impl Shrink for StreamCase {
    fn shrink(&self) -> Vec<Self> {
        if self.bytes.len() <= 1 {
            return Vec::new();
        }
        let half = self.bytes.len() / 2;
        vec![
            StreamCase {
                bytes: self.bytes[..half].to_vec(),
                chunks: self.chunks.clone(),
                max_frame: self.max_frame,
            },
            StreamCase {
                bytes: self.bytes[half..].to_vec(),
                chunks: self.chunks.clone(),
                max_frame: self.max_frame,
            },
        ]
    }
}

#[test]
fn decode_is_invariant_under_arbitrary_chunking() {
    check(
        "frame decode is byte-split invariant, malformed segments included",
        |rng| {
            let mut bytes = Vec::new();
            for _ in 0..rng.range(1, 12) {
                match rng.range(0, 4) {
                    // a well-formed request
                    0 | 1 => bytes.extend(encode_request(&WireRequest {
                        task: rng.range(0, 4) as u16,
                        sample: rng.range(0, 1000) as u32,
                        len_bucket: rng.range(0, 3) as u8,
                        arrival_ns: rng.range(0, 1_000_000) as u64,
                        corr: rng.range(0, 1 << 20) as u32,
                    })),
                    // a well-framed body with a corrupted header byte
                    2 => {
                        let mut f = encode_request(&wire_req(0, 0, 1));
                        let at = 4 + rng.range(0, 2);
                        f[at] ^= 0x5A;
                        bytes.extend(f);
                    }
                    // raw garbage: may desync into a fatal length prefix
                    _ => {
                        for _ in 0..rng.range(1, 30) {
                            bytes.push(rng.range(0, 256) as u8);
                        }
                    }
                }
            }
            // random cut widths; the tail chunk takes the remainder
            let chunks = (0..rng.range(1, 20)).map(|_| rng.range(1, 40)).collect();
            StreamCase { bytes, chunks, max_frame: rng.range(REQ_BODY_LEN, 256) }
        },
        |case| {
            // one-shot decode
            let mut one = FrameDecoder::new(case.max_frame);
            one.feed(&case.bytes);
            let mut want = Vec::new();
            drain_outcomes(&mut one, &mut want);

            // chunked decode: same bytes, arbitrary splits
            let mut d = FrameDecoder::new(case.max_frame);
            let mut got = Vec::new();
            let mut off = 0usize;
            let mut ci = 0usize;
            let mut fatal = false;
            while off < case.bytes.len() && !fatal {
                let w = case.chunks.get(ci).copied().unwrap_or(case.bytes.len());
                ci += 1;
                let end = (off + w).min(case.bytes.len());
                d.feed(&case.bytes[off..end]);
                off = end;
                fatal = drain_outcomes(&mut d, &mut got);
            }
            if got != want {
                return Err(format!("chunked {got:?} != one-shot {want:?}"));
            }
            if fatal {
                // fatal errors are sticky: the poisoned stream keeps
                // reporting fatal, consuming nothing
                match d.next_frame() {
                    Some(Err(FrameError::Fatal(_))) => {}
                    other => return Err(format!("fatal must be sticky, got {other:?}")),
                }
            }
            Ok(())
        },
    );
}

#[test]
fn oversize_length_prefix_is_fatal_for_the_connection_stream() {
    // the reactor-facing contract behind `drain_frames`: an oversize
    // prefix yields Fatal without consuming bytes, so a poisoned
    // connection can answer once and stop reading at a deterministic
    // stream position
    let mut d = FrameDecoder::new(64);
    d.feed(&encode_request(&wire_req(0, 3, 11)));
    d.feed(&(65u32).to_le_bytes());
    d.feed(&[0u8; 8]);
    let mut out = Vec::new();
    let fatal = drain_outcomes(&mut d, &mut out);
    assert!(fatal);
    assert_eq!(out.len(), 2, "the good frame decodes, then the stream dies: {out:?}");
    assert_eq!(out[0].as_ref().unwrap().corr, 11);
    assert!(matches!(out[1], Err(FrameError::Fatal(_))));
}

#[test]
fn wire_status_bytes_roundtrip() {
    for s in [
        WireStatus::Ok,
        WireStatus::Shed,
        WireStatus::Closed,
        WireStatus::Expired,
        WireStatus::Error,
    ] {
        assert_eq!(WireStatus::from_u8(s as u8).unwrap(), s);
    }
    assert!(WireStatus::from_u8(250).is_err());
    // response encoding roundtrips through the client reader
    let resp = proto::encode_response(&proto::WireResponse {
        corr: 77,
        status: WireStatus::Shed,
        pred: -1,
        lat_us: 42,
    });
    let got = read_response(&mut &resp[..]).unwrap();
    assert_eq!(got.corr, 77);
    assert_eq!(got.status, WireStatus::Shed);
}
