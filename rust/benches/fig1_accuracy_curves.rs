//! Regenerates paper Fig. 1: accuracy-vs-budget curves, one panel per task
//! (SVD vs AWQ vs SpQR vs Random, with FP32 ceiling and Q4 floor lines).
//! Panels are written to results/figures/fig1_<task>.txt. `harness = false`.

#[path = "common/mod.rs"]
mod common;

use svdquant::coordinator::sweep::{run_sweep, SweepConfig};
use svdquant::report;
use svdquant::runtime::Runtime;
use svdquant::util::bench::Bench;

fn main() {
    let Some(art) = common::artifacts_or_skip("fig1_accuracy_curves") else { return };
    let mut b = Bench::new("fig1_accuracy_curves").quick();
    let rt = Runtime::cpu().expect("pjrt");
    let out = std::path::PathBuf::from("results");
    let cfg = SweepConfig::paper_defaults(&art, &out);
    let res = run_sweep(&art, &rt, &cfg).expect("sweep");

    std::fs::create_dir_all("results/figures").ok();
    for task in art.tasks() {
        let panel = report::fig1_panel(&res, &task, &cfg.budgets);
        println!("{panel}");
        std::fs::write(format!("results/figures/fig1_{task}.txt"), &panel).ok();
        // shape checks the paper's qualitative claims (logged as table rows)
        let svd_hi = res.accuracy(&task, "svd", 4096).unwrap_or(0.0);
        let rand_hi = res.accuracy(&task, "random", 4096).unwrap_or(0.0);
        let floor = res.accuracy(&task, "q4_floor", 0).unwrap_or(0.0);
        b.table(
            &format!("fig1 shape checks ({task})"),
            vec!["check".into(), "value".into()],
            vec![
                vec!["svd@4096 - floor".into(), format!("{:+.4}", svd_hi - floor)],
                vec!["svd@4096 - random@4096".into(), format!("{:+.4}", svd_hi - rand_hi)],
            ],
        );
    }
    b.finish();
}
