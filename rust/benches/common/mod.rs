//! Shared helpers for the bench binaries (each bench is its own crate
//! root, so this module is include!'d by path).

use svdquant::coordinator::Artifacts;
use svdquant::data::Dataset;
use svdquant::json::Json;
use svdquant::model::{ModelConfig, Params};

/// Open artifacts or skip the bench gracefully (pre-`make artifacts` runs
/// of `cargo bench` must not fail the build pipeline).
#[allow(dead_code)]
pub fn artifacts_or_skip(bench: &str) -> Option<Artifacts> {
    match Artifacts::open("artifacts") {
        Ok(a) => Some(a),
        Err(e) => {
            println!("== bench: {bench} == SKIPPED (no artifacts: {e})");
            println!("   run `make artifacts` first");
            None
        }
    }
}

/// Serving-bench setup: the real mrpc checkpoint when artifacts exist,
/// otherwise the shared hermetic fixture (`svdquant::fixture`) — so the
/// serving perf trajectory (BENCH_serving.json) is tracked on every
/// machine, not just ones that ran `make artifacts`. The synthetic
/// fallback lives in the library so `rust/tests/serving.rs` runs the same
/// shapes under plain `cargo test -q`.
#[allow(dead_code)]
pub fn serving_setup() -> (ModelConfig, Params, Dataset, &'static str) {
    if let Ok(art) = Artifacts::open("artifacts") {
        if let (Ok(ckpt), Ok(dev)) = (art.checkpoint("mrpc"), art.dataset("mrpc", "dev")) {
            return (art.model_cfg, ckpt, dev, "artifacts:mrpc");
        }
    }
    let (cfg, params, dev) = svdquant::fixture::serving_fixture();
    (cfg, params, dev, "synthetic")
}

/// Sustained work-units/s of `f` over a ~`window_ms` wall-clock window
/// (shared by the JSON-trajectory measurements of the serving benches).
#[allow(dead_code)]
pub fn measure_units_per_s<R>(
    units_per_call: f64,
    window_ms: u64,
    mut f: impl FnMut() -> R,
) -> f64 {
    let t0 = std::time::Instant::now();
    let mut iters = 0u32;
    while t0.elapsed() < std::time::Duration::from_millis(window_ms) {
        std::hint::black_box(f());
        iters += 1;
    }
    units_per_call * iters as f64 / t0.elapsed().as_secs_f64()
}

/// Merge `section` into `results/BENCH_serving.json` under `key` — the
/// machine-readable serving-perf trajectory tracked across PRs. Each bench
/// overwrites only its own section, and every write refreshes the shared
/// `provenance` block so the file always records which kernel ISA
/// produced its numbers.
#[allow(dead_code)]
pub fn write_bench_serving(key: &str, section: Json) {
    let path = std::path::Path::new("results/BENCH_serving.json");
    let _ = std::fs::create_dir_all("results");
    let existing = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let mut pairs: Vec<(String, Json)> = existing
        .as_ref()
        .and_then(|j| j.as_object())
        .map(|m| m.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
        .unwrap_or_default();
    pairs.retain(|(k, _)| k != key && k != "provenance");
    pairs.push((key.to_string(), section));
    pairs.push((
        "provenance".to_string(),
        Json::object(vec![(
            "kernel_isa".to_string(),
            Json::from(svdquant::util::simd::active_isa().name()),
        )]),
    ));
    let doc = Json::object(pairs);
    match std::fs::write(path, doc.pretty()) {
        Ok(()) => println!("\n  serving trajectory -> {}", path.display()),
        Err(e) => svdquant::log_warn!("bench", "could not write {}: {e}", path.display()),
    }
}

/// One-task accuracy-table bench body (tables I–III share it).
#[allow(dead_code)] // each bench binary uses a subset of this module
pub fn table_bench(bench_name: &'static str, task: &str, paper_rows: &[(usize, f64, f64, f64)]) {
    use svdquant::coordinator::sweep::{run_sweep, SweepConfig};
    use svdquant::report;
    use svdquant::runtime::Runtime;
    use svdquant::util::bench::Bench;

    let Some(art) = artifacts_or_skip(bench_name) else { return };
    let mut b = Bench::new(bench_name).quick();
    let rt = Runtime::cpu().expect("pjrt client");
    let out = std::path::PathBuf::from("results");
    let mut cfg = SweepConfig::paper_defaults(&art, &out);
    cfg.tasks = vec![task.to_string()];
    cfg.methods =
        ["random", "awq", "spqr", "svd"].iter().map(|m| m.to_string()).collect();
    let res = run_sweep(&art, &rt, &cfg).expect("sweep");

    // rendered table (ours)
    let md = report::accuracy_table(&res, task, &cfg.budgets);
    println!("{md}");

    // ours-vs-paper rows for EXPERIMENTS.md
    let mut rows = Vec::new();
    for &(k, p_awq, p_spqr, p_svd) in paper_rows {
        let g = |m: &str| {
            res.accuracy(task, m, k)
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "—".into())
        };
        rows.push(vec![
            k.to_string(),
            format!("{p_awq:.4}"),
            g("awq"),
            format!("{p_spqr:.4}"),
            g("spqr"),
            format!("{p_svd:.4}"),
            g("svd"),
        ]);
    }
    b.table(
        &format!("{task}: paper vs measured"),
        ["k", "AWQ(paper)", "AWQ(ours)", "SpQR(paper)", "SpQR(ours)", "SVD(paper)", "SVD(ours)"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
    );
    b.finish();
}
