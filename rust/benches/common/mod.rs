//! Shared helpers for the bench binaries (each bench is its own crate
//! root, so this module is include!'d by path).

use svdquant::coordinator::Artifacts;

/// Open artifacts or skip the bench gracefully (pre-`make artifacts` runs
/// of `cargo bench` must not fail the build pipeline).
#[allow(dead_code)]
pub fn artifacts_or_skip(bench: &str) -> Option<Artifacts> {
    match Artifacts::open("artifacts") {
        Ok(a) => Some(a),
        Err(e) => {
            println!("== bench: {bench} == SKIPPED (no artifacts: {e})");
            println!("   run `make artifacts` first");
            None
        }
    }
}

/// One-task accuracy-table bench body (tables I–III share it).
#[allow(dead_code)] // each bench binary uses a subset of this module
pub fn table_bench(bench_name: &'static str, task: &str, paper_rows: &[(usize, f64, f64, f64)]) {
    use svdquant::coordinator::sweep::{run_sweep, SweepConfig};
    use svdquant::report;
    use svdquant::runtime::Runtime;
    use svdquant::util::bench::Bench;

    let Some(art) = artifacts_or_skip(bench_name) else { return };
    let mut b = Bench::new(bench_name).quick();
    let rt = Runtime::cpu().expect("pjrt client");
    let out = std::path::PathBuf::from("results");
    let mut cfg = SweepConfig::paper_defaults(&art, &out);
    cfg.tasks = vec![task.to_string()];
    cfg.methods =
        ["random", "awq", "spqr", "svd"].iter().map(|m| m.to_string()).collect();
    let res = run_sweep(&art, &rt, &cfg).expect("sweep");

    // rendered table (ours)
    let md = report::accuracy_table(&res, task, &cfg.budgets);
    println!("{md}");

    // ours-vs-paper rows for EXPERIMENTS.md
    let mut rows = Vec::new();
    for &(k, p_awq, p_spqr, p_svd) in paper_rows {
        let g = |m: &str| {
            res.accuracy(task, m, k)
                .map(|a| format!("{a:.4}"))
                .unwrap_or_else(|| "—".into())
        };
        rows.push(vec![
            k.to_string(),
            format!("{p_awq:.4}"),
            g("awq"),
            format!("{p_spqr:.4}"),
            g("spqr"),
            format!("{p_svd:.4}"),
            g("svd"),
        ]);
    }
    b.table(
        &format!("{task}: paper vs measured"),
        ["k", "AWQ(paper)", "AWQ(ours)", "SpQR(paper)", "SpQR(ours)", "SVD(paper)", "SVD(ours)"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
    );
    b.finish();
}
