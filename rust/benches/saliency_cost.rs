//! Paper §VI-A complexity comparison, measured: selection cost of each
//! heuristic over transformer-shaped weight matrices, driven through the
//! [`Scorer`] trait (the same code path the pipeline uses).
//!
//! * SVD (randomized, O(r·d²)) — the paper's fast static path
//! * SVD (exact Jacobi, O(d³)) — the naive alternative
//! * SpQR — Hessian Cholesky + inverse diagonal, O(d³), *plus* it needs a
//!   calibration forward pass that the static methods don't pay
//! * AWQ — trivial given colnorms, but colnorms require the forward pass
//! * top-k selection — shared epilogue
//!
//! Also measures the `QuantizePipeline`'s layer-parallel scoring (1 thread
//! vs available parallelism, plus the warm-cache hit), the rank-r ablation
//! and the calibration-size ablation (DESIGN.md §5). `harness = false`.

use svdquant::calib::{CalibStats, LayerStats};
use svdquant::coordinator::QuantizePipeline;
use svdquant::linalg::{matmul_at_b, Matrix};
use svdquant::model::params::testing::synthetic_params;
use svdquant::model::ModelConfig;
use svdquant::saliency::{
    select_topk, AwqScorer, ScoreCtx, Scorer, SpqrScorer, SvdScoreMode, SvdScorer,
};
use svdquant::util::bench::Bench;
use svdquant::util::rng::Rng;

fn transformer_like(rng: &mut Rng, dout: usize, din: usize) -> Matrix {
    // low-rank head + noise tail, like trained attention/FFN weights
    let r = 12.min(dout.min(din));
    let mut u = Matrix::zeros(dout, r);
    rng.fill_normal(u.data_mut(), 0.2);
    let mut v = Matrix::zeros(r, din);
    rng.fill_normal(v.data_mut(), 0.2);
    let mut w = u.dot(&v);
    let mut noise = Matrix::zeros(dout, din);
    rng.fill_normal(noise.data_mut(), 0.02);
    w = w.add(&noise);
    w
}

/// Synthetic calibration stats over activations `x`, registered for one
/// pseudo-layer named `"bench"` (feeds the data-aware scorers).
fn bench_calib(x: &Matrix) -> CalibStats {
    let col_sumsq: Vec<f64> = (0..x.cols())
        .map(|j| x.col(j).iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect();
    let mut calib = CalibStats::default();
    calib.layers.insert(
        "bench".to_string(),
        LayerStats { col_sumsq, xtx: matmul_at_b(x, x), rows: x.rows() },
    );
    calib.samples = x.rows() / 48;
    calib
}

fn main() {
    let mut b = Bench::new("saliency_cost");
    let mut rng = Rng::new(0xC057);

    for &(dout, din) in &[(256usize, 256usize), (1024, 256), (256, 1024)] {
        let w = transformer_like(&mut rng, dout, din);
        let label = format!("{dout}x{din}");
        // synthetic calibration activations: 6144 tokens (128 seqs × 48)
        let n_tok = 6144;
        let mut x = Matrix::zeros(n_tok, din);
        rng.fill_normal(x.data_mut(), 1.0);
        let calib = bench_calib(&x);
        let ctx = ScoreCtx::with_calib(&calib);

        let svd_fast = SvdScorer::new(8, SvdScoreMode::default());
        let svd_exact = SvdScorer::new(8, SvdScoreMode::Exact);
        let spqr = SpqrScorer::new(0.01);
        let awq = AwqScorer;

        b.timeit(&format!("svd_rsvd_r8      {label}"), || {
            svd_fast.score("bench", &w, &ctx).unwrap()
        });
        b.timeit(&format!("svd_exact        {label}"), || {
            svd_exact.score("bench", &w, &ctx).unwrap()
        });
        // SpQR cost split: (a) XᵀX build (calibration-time), (b) inverse
        b.timeit(&format!("spqr_xtx_build   {label}"), || matmul_at_b(&x, &x));
        b.timeit(&format!("spqr_inverse     {label}"), || {
            spqr.score("bench", &w, &ctx).unwrap()
        });
        b.timeit(&format!("awq_score        {label}"), || {
            awq.score("bench", &w, &ctx).unwrap()
        });
        let score = svd_fast.score("bench", &w, &ctx).unwrap();
        b.timeit(&format!("topk_k4096       {label}"), || select_topk(&score, 4096));
    }

    // --- pipeline scoring throughput: 1 thread vs available parallelism --
    let mcfg = ModelConfig::default();
    let ckpt = synthetic_params(&mcfg, 0x5CA1E);
    let n_layers = mcfg.quantizable_names().len();
    for threads in [1usize, 0] {
        // cap the whole global pool, not just the pipeline's scoring batch:
        // the scorers' inner kernels (rsvd range-finder matmuls) fan out on
        // the shared pool, so without this the "1 thread" row would still
        // run those multi-core (exactly how main.rs's apply_threads wires
        // --threads)
        svdquant::util::pool::set_global_parallelism(threads);
        let mut pipe = QuantizePipeline::for_checkpoint(&mcfg, &ckpt)
            .scorer(Box::new(SvdScorer::new(8, SvdScoreMode::default())))
            .threads(threads)
            .build()
            .expect("pipeline");
        let name =
            format!("pipeline svd scoring {n_layers} layers, {} thread(s)", pipe.threads());
        b.timeit_throughput(&name, n_layers as f64, "layer", || {
            // fresh maps each iteration so the measurement is pure scoring
            pipe.clear_score_cache();
            pipe.ensure_scores().expect("score")
        });
    }
    svdquant::util::pool::set_global_parallelism(0);
    {
        let mut pipe = QuantizePipeline::for_checkpoint(&mcfg, &ckpt)
            .scorer(Box::new(SvdScorer::new(8, SvdScoreMode::default())))
            .build()
            .expect("pipeline");
        pipe.ensure_scores().expect("score");
        b.timeit(&format!("pipeline warm-cache hit ({n_layers} layers)"), || {
            pipe.ensure_scores().expect("score")
        });
    }

    // --- rank ablation: does the score stabilize with r? -----------------
    let w = transformer_like(&mut rng, 256, 1024);
    let ctx = ScoreCtx::data_free();
    let exact_8 = select_topk(
        &SvdScorer::new(8, SvdScoreMode::Exact).score("ablate", &w, &ctx).unwrap(),
        1024,
    );
    let mut rows = Vec::new();
    for r in [1usize, 2, 4, 8, 16, 32] {
        let scorer = SvdScorer::new(r, SvdScoreMode::default());
        let t = std::time::Instant::now();
        let s = scorer.score("ablate", &w, &ctx).unwrap();
        let dt = t.elapsed().as_secs_f64();
        let sel = select_topk(&s, 1024);
        let agreement = svdquant::saliency::iou(&sel, &exact_8);
        rows.push(vec![
            r.to_string(),
            format!("{:.1} ms", dt * 1e3),
            format!("{agreement:.3}"),
        ]);
    }
    b.table(
        "rank ablation (256x1024, k=1024): IoU vs exact r=8 selection",
        vec!["r".into(), "rsvd time".into(), "IoU vs exact-r8".into()],
        rows,
    );

    // --- calibration-size sensitivity (supports the paper's RTE story) ---
    let mut rows = Vec::new();
    let full_n = 6144;
    let mut x = Matrix::zeros(full_n, 256);
    rng.fill_normal(x.data_mut(), 1.0);
    let w = transformer_like(&mut rng, 256, 256);
    let spqr = SpqrScorer::new(0.01);
    let full_calib = bench_calib(&x);
    let ref_sel = select_topk(
        &spqr.score("bench", &w, &ScoreCtx::with_calib(&full_calib)).unwrap(),
        1024,
    );
    for n in [384usize, 1536, 6144] {
        let calib = bench_calib(&x.slice_rows(0, n));
        let sel = select_topk(
            &spqr.score("bench", &w, &ScoreCtx::with_calib(&calib)).unwrap(),
            1024,
        );
        rows.push(vec![
            format!("{} tokens (~{} seqs)", n, n / 48),
            format!("{:.3}", svdquant::saliency::iou(&sel, &ref_sel)),
        ]);
    }
    b.table(
        "SpQR calibration-size sensitivity: selection IoU vs full-calib selection",
        vec!["calib size".into(), "IoU vs full".into()],
        rows,
    );
    b.finish();
}
