//! Paper §VI-A complexity comparison, measured: selection cost of each
//! heuristic over transformer-shaped weight matrices.
//!
//! * SVD (randomized, O(r·d²)) — the paper's fast static path
//! * SVD (exact Jacobi, O(d³)) — the naive alternative
//! * SpQR — Hessian Cholesky + inverse diagonal, O(d³), *plus* it needs a
//!   calibration forward pass that the static methods don't pay
//! * AWQ — trivial given colnorms, but colnorms require the forward pass
//! * top-k selection — shared epilogue
//!
//! Also runs the calibration-size ablation (DESIGN.md §5) and the
//! rank-r ablation for the SVD score. `harness = false`.

use svdquant::linalg::{matmul_at_b, Matrix};
use svdquant::saliency::{awq_score, select_topk, spqr_score, svd_score, SvdScoreMode};
use svdquant::util::bench::Bench;
use svdquant::util::rng::Rng;

fn transformer_like(rng: &mut Rng, dout: usize, din: usize) -> Matrix {
    // low-rank head + noise tail, like trained attention/FFN weights
    let r = 12.min(dout.min(din));
    let mut u = Matrix::zeros(dout, r);
    rng.fill_normal(u.data_mut(), 0.2);
    let mut v = Matrix::zeros(r, din);
    rng.fill_normal(v.data_mut(), 0.2);
    let mut w = u.dot(&v);
    let mut noise = Matrix::zeros(dout, din);
    rng.fill_normal(noise.data_mut(), 0.02);
    w = w.add(&noise);
    w
}

fn main() {
    let mut b = Bench::new("saliency_cost");
    let mut rng = Rng::new(0xC057);

    for &(dout, din) in &[(256usize, 256usize), (1024, 256), (256, 1024)] {
        let w = transformer_like(&mut rng, dout, din);
        let label = format!("{dout}x{din}");
        // synthetic calibration activations: 6144 tokens (128 seqs × 48)
        let n_tok = 6144;
        let mut x = Matrix::zeros(n_tok, din);
        rng.fill_normal(x.data_mut(), 1.0);

        b.timeit(&format!("svd_rsvd_r8      {label}"), || {
            svd_score(&w, 8, SvdScoreMode::default())
        });
        b.timeit(&format!("svd_exact        {label}"), || {
            svd_score(&w, 8, SvdScoreMode::Exact)
        });
        // SpQR cost split: (a) XᵀX build (calibration-time), (b) inverse
        let xtx = matmul_at_b(&x, &x);
        b.timeit(&format!("spqr_xtx_build   {label}"), || matmul_at_b(&x, &x));
        b.timeit(&format!("spqr_inverse     {label}"), || {
            spqr_score(&w, &xtx, n_tok, 0.01)
        });
        let colnorm: Vec<f32> = (0..din)
            .map(|j| x.col(j).iter().map(|v| v * v).sum::<f32>().sqrt())
            .collect();
        b.timeit(&format!("awq_score        {label}"), || awq_score(&w, &colnorm));
        let score = svd_score(&w, 8, SvdScoreMode::default());
        b.timeit(&format!("topk_k4096       {label}"), || select_topk(&score, 4096));
    }

    // --- rank ablation: does the score stabilize with r? -----------------
    let w = transformer_like(&mut rng, 256, 1024);
    let exact_8 = select_topk(&svd_score(&w, 8, SvdScoreMode::Exact), 1024);
    let mut rows = Vec::new();
    for r in [1usize, 2, 4, 8, 16, 32] {
        let t = std::time::Instant::now();
        let s = svd_score(&w, r, SvdScoreMode::default());
        let dt = t.elapsed().as_secs_f64();
        let sel = select_topk(&s, 1024);
        let agreement = svdquant::saliency::iou(&sel, &exact_8);
        rows.push(vec![
            r.to_string(),
            format!("{:.1} ms", dt * 1e3),
            format!("{agreement:.3}"),
        ]);
    }
    b.table(
        "rank ablation (256x1024, k=1024): IoU vs exact r=8 selection",
        vec!["r".into(), "rsvd time".into(), "IoU vs exact-r8".into()],
        rows,
    );

    // --- calibration-size sensitivity (supports the paper's RTE story) ---
    let mut rows = Vec::new();
    let full_n = 6144;
    let mut x = Matrix::zeros(full_n, 256);
    rng.fill_normal(x.data_mut(), 1.0);
    let w = transformer_like(&mut rng, 256, 256);
    let xtx_full = matmul_at_b(&x, &x);
    let ref_sel = select_topk(&spqr_score(&w, &xtx_full, full_n, 0.01), 1024);
    for n in [384usize, 1536, 6144] {
        let xs = x.slice_rows(0, n);
        let xtx = matmul_at_b(&xs, &xs);
        let sel = select_topk(&spqr_score(&w, &xtx, n, 0.01), 1024);
        rows.push(vec![
            format!("{} tokens (~{} seqs)", n, n / 48),
            format!("{:.3}", svdquant::saliency::iou(&sel, &ref_sel)),
        ]);
    }
    b.table(
        "SpQR calibration-size sensitivity: selection IoU vs full-calib selection",
        vec!["calib size".into(), "IoU vs full".into()],
        rows,
    );
    b.finish();
}
