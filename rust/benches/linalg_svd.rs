//! Linalg substrate roofline: blocked matmul GFLOP/s (the ceiling every
//! other kernel is judged against), Jacobi SVD and randomized SVD scaling,
//! Cholesky + inverse-diagonal (the SpQR kernel). `harness = false`.

use svdquant::linalg::{
    cholesky, inverse_diagonal, matmul, matmul_a_bt, qr_thin, rsvd, svd_jacobi, Matrix,
};
use svdquant::util::bench::Bench;
use svdquant::util::rng::Rng;

fn rand_m(rng: &mut Rng, r: usize, c: usize) -> Matrix {
    let mut m = Matrix::zeros(r, c);
    rng.fill_normal(m.data_mut(), 1.0);
    m
}

fn main() {
    let mut b = Bench::new("linalg_svd");
    let mut rng = Rng::new(0x11A6);

    for &n in &[128usize, 256, 512] {
        let a = rand_m(&mut rng, n, n);
        let c = rand_m(&mut rng, n, n);
        let flops = 2.0 * (n as f64).powi(3);
        b.timeit_throughput(&format!("matmul {n}³"), flops, "flop", || matmul(&a, &c));
        b.timeit_throughput(&format!("matmul_a_bt {n}³"), flops, "flop", || {
            matmul_a_bt(&a, &c)
        });
    }

    for &(m, n) in &[(256usize, 64usize), (1024, 16)] {
        let a = rand_m(&mut rng, m, n);
        b.timeit(&format!("qr_thin {m}x{n}"), || qr_thin(&a));
    }

    for &(m, n) in &[(64usize, 64usize), (128, 128), (256, 256)] {
        let a = rand_m(&mut rng, m, n);
        b.timeit(&format!("svd_jacobi {m}x{n}"), || svd_jacobi(&a));
    }

    for &(m, n) in &[(256usize, 256usize), (256, 1024), (1024, 1024)] {
        let a = rand_m(&mut rng, m, n);
        b.timeit(&format!("rsvd_r8 {m}x{n}"), || rsvd(&a, 8, 8, 2, 1));
    }

    for &n in &[256usize, 1024] {
        let x = rand_m(&mut rng, 2 * n, n);
        let mut spd = svdquant::linalg::matmul_at_b(&x, &x);
        for i in 0..n {
            spd[(i, i)] += n as f32 * 0.01;
        }
        let l = cholesky(&spd).unwrap();
        b.timeit(&format!("cholesky {n}²"), || cholesky(&spd).unwrap());
        b.timeit(&format!("inverse_diagonal {n}²"), || inverse_diagonal(&l));
    }
    b.finish();
}
