//! Regenerates paper Table II (RTE accuracy recovery vs protection budget),
//! the task where the paper's SVD method crosses above the FP32 baseline
//! at k=4096 (the §VI-B "regularization effect"). `harness = false`.
#[path = "common/mod.rs"]
mod common;

fn main() {
    // paper Table II rows: (k, AWQ, SpQR, SVD)
    let paper = [
        (1usize, 0.6498, 0.6498, 0.6354),
        (16, 0.6390, 0.6426, 0.6390),
        (64, 0.6426, 0.6426, 0.6498),
        (256, 0.6390, 0.6426, 0.6426),
        (1024, 0.6498, 0.6426, 0.6498),
        (4096, 0.6534, 0.6534, 0.6606),
    ];
    common::table_bench("table2_rte", "rte", &paper);
}
