//! Deploy-path benches: engine forward latency (fp32 vs packed-int4
//! fused), PJRT executable latency, and the batching server under Poisson
//! and bursty traces — the paper's deployment headline (compressed model,
//! served). `harness = false`.

#[path = "common/mod.rs"]
mod common;

use std::time::Duration;

use svdquant::coordinator::server::{serve_trace, ServerConfig};
use svdquant::coordinator::QuantizePipeline;
use svdquant::data::TraceGenerator;
use svdquant::eval::eval_pjrt;
use svdquant::model::{Engine, QuantizedModel};
use svdquant::quant::QuantConfig;
use svdquant::runtime::Runtime;
use svdquant::util::bench::Bench;

fn main() {
    let Some(art) = common::artifacts_or_skip("engine_inference") else { return };
    let mut b = Bench::new("engine_inference").quick();
    let task = "mrpc";
    let ckpt = art.checkpoint(task).expect("ckpt");
    let dev = art.dataset(task, "dev").expect("dev");
    let cfg = art.model_cfg;

    let qcfg = QuantConfig::default();
    let (qp, sels) = {
        // data-free SVD selection at k=256 through the staged pipeline
        let mut pipe = QuantizePipeline::for_checkpoint(&cfg, &ckpt)
            .budget(256)
            .quant(qcfg)
            .build()
            .expect("pipeline");
        pipe.run().expect("quantize")
    };
    let engine = Engine::new(cfg, ckpt.clone()).expect("engine");
    let qm = QuantizedModel::build(cfg, ckpt.clone(), &qcfg, &sels).expect("qm");
    let (qb, db) = qm.quantized_bytes();
    println!(
        "  weights: dense {} -> packed {} ({:.2}x)",
        svdquant::util::human_bytes(db),
        svdquant::util::human_bytes(qb),
        db as f64 / qb as f64
    );

    for &batch in &[1usize, 8, 16] {
        let (ids, mask) = dev.batch_slices(0, batch);
        b.timeit_throughput(&format!("engine fp32 fwd b={batch}"), batch as f64, "seq", || {
            engine.forward(&ids, &mask).unwrap()
        });
        b.timeit_throughput(&format!("engine int4-fused fwd b={batch}"), batch as f64, "seq", || {
            qm.forward_fused(&ids, &mask).unwrap()
        });
    }

    // PJRT path (the sweep engine)
    let rt = Runtime::cpu().expect("pjrt");
    let exe = art.compile_model(&rt, task, false).expect("compile");
    let small = {
        // eval over one export batch worth of samples
        let n = cfg.export_batch.min(dev.len());
        let (ids, mask) = dev.batch_slices(0, n);
        let labels = dev.labels()[..n].to_vec();
        svdquant::data::Dataset::from_raw("bench", ids, mask, labels, cfg.max_len).unwrap()
    };
    b.timeit_throughput(
        &format!("pjrt eval {} seqs (weights as args)", small.len()),
        small.len() as f64,
        "seq",
        || eval_pjrt(&exe, &cfg, &qp, &small).unwrap(),
    );

    // serving under load
    let mut rows = Vec::new();
    for (name, gen, rate) in [
        ("poisson@30", TraceGenerator::poisson(30.0), 30.0),
        ("poisson@80", TraceGenerator::poisson(80.0), 80.0),
        ("bursty@30", TraceGenerator::bursty(30.0, 0.25, 8), 30.0),
    ] {
        let trace = gen.generate(120, dev.len(), 0xBE9C);
        let scfg = ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
        };
        let s = serve_trace(&qm, &dev, &trace, &scfg).expect("serve");
        rows.push(vec![
            name.to_string(),
            format!("{rate:.0}"),
            format!("{:.1}", s.throughput_rps),
            format!("{:.1}", s.p50_ms),
            format!("{:.1}", s.p95_ms),
            format!("{:.1}", s.mean_batch),
            format!("{:.4}", s.accuracy),
        ]);
    }
    b.table(
        "serving (svd k=256 packed int4, single worker)",
        ["trace", "offered rps", "achieved rps", "p50 ms", "p95 ms", "mean batch", "acc"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        rows,
    );

    // batching ablation: max_batch sensitivity under the same trace
    let mut rows = Vec::new();
    let trace = TraceGenerator::bursty(60.0, 0.25, 8).generate(120, dev.len(), 0xAB);
    for mb in [1usize, 4, 16] {
        let scfg = ServerConfig {
            max_batch: mb,
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
        };
        let s = serve_trace(&qm, &dev, &trace, &scfg).expect("serve");
        rows.push(vec![
            mb.to_string(),
            format!("{:.1}", s.throughput_rps),
            format!("{:.1}", s.p95_ms),
            format!("{:.1}", s.mean_batch),
        ]);
    }
    b.table(
        "batching ablation (bursty@60)",
        ["max_batch", "rps", "p95 ms", "mean batch"].iter().map(|s| s.to_string()).collect(),
        rows,
    );
    b.finish();
}
