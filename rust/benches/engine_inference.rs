//! Deploy-path benches: engine forward latency (fp32 vs packed fused,
//! float vs integer kernel, per residual width 2/3/4/8), PJRT executable
//! latency (artifacts only), the multi-worker batching server under load
//! (kernel × threads × workers), and a virtual-time replay of the same
//! trace — the paper's deployment headline (compressed model, served).
//! `harness = false`.
//!
//! Always runs: when `make artifacts` hasn't been executed the bench falls
//! back to a synthetic shape-realistic checkpoint, so the serving perf
//! trajectory (`results/BENCH_serving.json`) is tracked on every machine.

#[path = "common/mod.rs"]
mod common;

use std::time::Duration;

use svdquant::coordinator::server::{
    serve, serve_trace, ChaosPlan, Registry, SchedPolicy, ServeStats, ServerConfig,
    ServiceModel,
};
use svdquant::coordinator::QuantizePipeline;
use svdquant::data::TraceGenerator;
use svdquant::json::Json;
use svdquant::model::{Engine, QuantizedModel};
use svdquant::quant::{GemmKernel, QuantConfig};
use svdquant::util::bench::Bench;
use svdquant::util::clock::Clock;
use svdquant::util::pool;

fn main() {
    let mut b = Bench::new("engine_inference").quick();
    let (cfg, ckpt, dev, source) = common::serving_setup();
    println!("  model source: {source} (hidden {}, layers {})", cfg.hidden, cfg.layers);

    let qcfg = QuantConfig::default();
    // data-free SVD selection at k=256 through the staged pipeline; kept
    // alive so the artifacts-only PJRT section below reuses the memoized
    // score maps instead of re-scoring every layer
    let mut pipe = QuantizePipeline::for_checkpoint(&cfg, &ckpt)
        .budget(256)
        .quant(qcfg)
        .build()
        .expect("pipeline");
    let sels = pipe.select(256).expect("select");
    let engine = Engine::new(cfg, ckpt.clone()).expect("engine");
    // one quantized model; kernel comparisons flip set_kernel in place
    // instead of re-packing every layer
    let mut qm = QuantizedModel::build(cfg, ckpt.clone(), &qcfg, &sels).expect("qm");
    let (qb, db) = qm.quantized_bytes();
    println!(
        "  weights: dense {} -> packed {} ({:.2}x)",
        svdquant::util::human_bytes(db),
        svdquant::util::human_bytes(qb),
        db as f64 / qb as f64
    );

    // ---- forward latency: fp32 vs fused-f32 vs fused-int8 ----------------
    let mut fwd_section: Vec<(String, f64)> = Vec::new();
    for &batch in &[1usize, 8, 16] {
        let (ids, mask) = dev.batch_slices(0, batch);
        b.timeit_throughput(&format!("engine fp32 fwd b={batch}"), batch as f64, "seq", || {
            engine.forward(&ids, &mask).unwrap()
        });
        for (kernel, name) in [(GemmKernel::F32, "f32"), (GemmKernel::Int8, "int8")] {
            qm.set_kernel(kernel);
            b.timeit_throughput(
                &format!("fused {name}-kernel fwd b={batch}"),
                batch as f64,
                "seq",
                || qm.forward_fused(&ids, &mask).unwrap(),
            );
            // quick seq/s number for the JSON trajectory
            let seq_per_s = common::measure_units_per_s(batch as f64, 120, || {
                qm.forward_fused(&ids, &mask).unwrap()
            });
            fwd_section.push((format!("fused_{name}_b{batch}_seq_per_s"), seq_per_s));
        }
    }

    // ---- forward latency per residual width ------------------------------
    // the mixed-precision axis: one packed model per supported width, int8
    // kernel, b=16 — how much serving throughput each allocator-assignable
    // width costs (4-bit runs the SIMD nibble expand, 2/3 the unrolled
    // decoders, 8 a byte copy)
    let mut width_fwd: Vec<(String, Json)> = Vec::new();
    {
        let (ids, mask) = dev.batch_slices(0, 16);
        qm.set_kernel(GemmKernel::Int8);
        for bits in svdquant::quant::SUPPORTED_BITS {
            // the default width reuses the already-packed model above
            let built = (bits != qcfg.bits).then(|| {
                QuantizedModel::build(cfg, ckpt.clone(), &qcfg.with_bits(bits), &sels)
                    .expect("width model")
            });
            let qm_b = built.as_ref().unwrap_or(&qm);
            b.timeit_throughput(
                &format!("fused int8-kernel fwd b=16 ({bits}-bit codes)"),
                16.0,
                "seq",
                || qm_b.forward_fused(&ids, &mask).unwrap(),
            );
            let seq_per_s = common::measure_units_per_s(16.0, 120, || {
                qm_b.forward_fused(&ids, &mask).unwrap()
            });
            width_fwd.push((format!("fused_int8_w{bits}_b16_seq_per_s"), Json::from(seq_per_s)));
        }
    }

    // ---- fused forward: scalar-forced vs SIMD dispatch -------------------
    // the end-to-end view of the kernel-ISA speedup (quant_throughput has
    // the isolated igemm number): same model, same batch, dispatch forced
    // scalar vs the resolved hardware arm — logits asserted bitwise equal,
    // so the delta is pure kernel speed
    let simd_fwd = {
        use svdquant::util::simd;
        let (ids, mask) = dev.batch_slices(0, 16);
        qm.set_kernel(GemmKernel::Int8);
        let (scalar_seq_s, scalar_out) = {
            let _g = simd::override_isa(simd::Isa::Scalar);
            b.timeit_throughput("fused int8 fwd b=16 (forced scalar)", 16.0, "seq", || {
                qm.forward_fused(&ids, &mask).unwrap()
            });
            let s = common::measure_units_per_s(16.0, 120, || {
                qm.forward_fused(&ids, &mask).unwrap()
            });
            (s, qm.forward_fused(&ids, &mask).unwrap())
        };
        let isa = simd::active_isa();
        b.timeit_throughput(
            &format!("fused int8 fwd b=16 ({})", isa.name()),
            16.0,
            "seq",
            || qm.forward_fused(&ids, &mask).unwrap(),
        );
        let simd_seq_s = common::measure_units_per_s(16.0, 120, || {
            qm.forward_fused(&ids, &mask).unwrap()
        });
        let simd_out = qm.forward_fused(&ids, &mask).unwrap();
        assert_eq!(
            simd_out.max_abs_diff(&scalar_out),
            0.0,
            "SIMD and scalar fused forwards must be bitwise identical"
        );
        Json::object(vec![
            ("kernel_isa".to_string(), Json::from(isa.name())),
            ("fused_int8_b16_scalar_seq_per_s".to_string(), Json::from(scalar_seq_s)),
            ("fused_int8_b16_simd_seq_per_s".to_string(), Json::from(simd_seq_s)),
            (
                "simd_speedup".to_string(),
                Json::from(simd_seq_s / scalar_seq_s.max(1e-12)),
            ),
        ])
    };

    // ---- PJRT path (artifacts + real xla crate only) ---------------------
    if source.starts_with("artifacts") {
        if let Ok(art) = svdquant::coordinator::Artifacts::open("artifacts") {
            if let Ok(rt) = svdquant::runtime::Runtime::cpu() {
                if let Ok(exe) = art.compile_model(&rt, "mrpc", false) {
                    let n = cfg.export_batch.min(dev.len());
                    let (ids, mask) = dev.batch_slices(0, n);
                    let labels = dev.labels()[..n].to_vec();
                    let small = svdquant::data::Dataset::from_raw(
                        "bench", ids, mask, labels, cfg.max_len,
                    )
                    .unwrap();
                    // score maps are already memoized from the select above
                    let (qp, _) = pipe.run().expect("quantize");
                    b.timeit_throughput(
                        &format!("pjrt eval {} seqs (weights as args)", small.len()),
                        small.len() as f64,
                        "seq",
                        || svdquant::eval::eval_pjrt(&exe, &cfg, &qp, &small).unwrap(),
                    );
                }
            } else {
                println!("  (pjrt path skipped: stub xla crate)");
            }
        }
    }

    // ---- serving under load: kernel × threads × workers ------------------
    // offered rate is set above single-thread capacity so achieved rps
    // reflects kernel + thread scaling, not the arrival process. Workers
    // scale batch pipelining; threads scale within-batch kernel fan-out —
    // the grid varies each axis with the other held fixed so a regression
    // in either is attributable from the JSON trajectory alone.
    let trace = TraceGenerator::poisson(400.0).generate(160, dev.len(), 0xBE9C);
    let mut rows = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    for &(threads, workers) in &[(1usize, 1usize), (4, 1), (1, 2), (4, 2)] {
        pool::set_global_parallelism(threads);
        let scfg = ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 512,
            workers,
            deadline: None,
            clock: Clock::wall(),
            ..ServerConfig::default()
        };
        for (kernel, name) in [(GemmKernel::F32, "f32"), (GemmKernel::Int8, "int8")] {
            qm.set_kernel(kernel);
            let s = serve_trace(&qm, &dev, &trace, &scfg).expect("serve");
            let tokens_s = s.completions as f64 * cfg.max_len as f64 / s.wall_s.max(1e-9);
            rows.push(vec![
                name.to_string(),
                threads.to_string(),
                workers.to_string(),
                format!("{:.1}", s.throughput_rps),
                format!("{tokens_s:.0}"),
                format!("{:.1}", s.p50_ms),
                format!("{:.1}", s.p95_ms),
                format!("{:.1}", s.mean_batch),
                s.shed.to_string(),
                format!("{:.4}", s.accuracy),
            ]);
            json_rows.push(serve_stats_json(name, threads, workers, &s, tokens_s));
        }
    }
    pool::set_global_parallelism(0);
    b.table(
        "serving (svd k=256 packed int4, poisson@400, kernel x threads x workers)",
        [
            "kernel", "threads", "workers", "rps", "tokens/s", "p50 ms", "p95 ms",
            "mean batch", "shed", "acc",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
        rows,
    );

    // ---- virtual-time replay: the hermetic-test path ---------------------
    // the same trace replayed on a virtual clock: arrival pacing and
    // batcher deadlines advance the timeline instead of sleeping, so the
    // real cost is pure compute — this wall time is what the serving test
    // suite pays per trace.
    qm.set_kernel(GemmKernel::Int8);
    let vcfg = ServerConfig {
        max_batch: 16,
        max_wait: Duration::from_millis(4),
        queue_cap: 512,
        workers: 2,
        deadline: None,
        clock: Clock::virt(),
        ..ServerConfig::default()
    };
    let t0 = std::time::Instant::now();
    let vs = serve_trace(&qm, &dev, &trace, &vcfg).expect("virtual serve");
    let virt_wall_s = t0.elapsed().as_secs_f64();
    println!(
        "  virtual replay: {} completions of a {:.2}s-span trace in {:.3}s real \
         ({:.0}x faster than real time)",
        vs.completions,
        vs.wall_s,
        virt_wall_s,
        vs.wall_s / virt_wall_s.max(1e-9)
    );

    // ---- tracing overhead: off vs sampled vs full span recording ---------
    // the same virtual replay with per-request span tracing disabled,
    // sampled 1-in-16, and full: the delta is the observability tax on the
    // serving hot path (ring pushes + one now_ns read per event). Gated at
    // < 5% of the trace-off wall time (with a 5ms absolute slack floor, so
    // sub-resolution jitter on a short replay can't fail the gate).
    let obs_json = {
        qm.set_kernel(GemmKernel::Int8);
        let mut measure = |tracing: Option<svdquant::obs::TraceSpec>| {
            let scfg = ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                workers: 2,
                clock: Clock::virt(),
                tracing,
                ..ServerConfig::default()
            };
            let mut best_s = f64::INFINITY;
            let mut completions = 0usize;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                let s = serve_trace(&qm, &dev, &trace, &scfg).expect("obs serve");
                best_s = best_s.min(t0.elapsed().as_secs_f64());
                completions = s.completions;
            }
            (completions as f64 * cfg.max_len as f64 / best_s.max(1e-9), best_s)
        };
        let (off_tps, off_s) = measure(None);
        let (sampled_tps, _) = measure(Some(svdquant::obs::TraceSpec {
            ring_cap: 1 << 16,
            sample_every: 16,
        }));
        let (full_tps, full_s) = measure(Some(svdquant::obs::TraceSpec {
            ring_cap: 1 << 16,
            sample_every: 1,
        }));
        let overhead = (full_s - off_s) / off_s.max(1e-9);
        println!(
            "  tracing overhead: off {off_tps:.0} tok/s, sampled(1/16) {sampled_tps:.0}, \
             full {full_tps:.0} ({:+.1}% wall)",
            overhead * 1e2
        );
        assert!(
            full_s - off_s < (0.05 * off_s).max(0.005),
            "full span tracing costs {:.1}% of the untraced serve (> 5% gate)",
            overhead * 1e2
        );
        Json::object(vec![
            ("tokens_per_s_trace_off".to_string(), Json::from(off_tps)),
            ("tokens_per_s_trace_sampled_16".to_string(), Json::from(sampled_tps)),
            ("tokens_per_s_trace_full".to_string(), Json::from(full_tps)),
            ("full_overhead_fraction".to_string(), Json::from(overhead)),
            ("gate_full_overhead_lt_0p05".to_string(), Json::from(true)),
        ])
    };

    // ---- capacity-planning curves: offered load vs p99 / shed / SLO ------
    // the serving stack as a discrete-event simulation: the measured int8
    // forward costs calibrate a ServiceModel (cost(b) ≈ base + per_req·b),
    // then a heavy-tailed three-tenant trace is swept across load multiples
    // of modeled capacity on the virtual clock — thousands of simulated
    // requests per point for milliseconds of real time. FIFO and EDF run on
    // identical traces, so the SLO-attainment gap at each point is
    // attributable to head selection alone.
    let capacity_json = {
        let lookup = |key: &str| {
            fwd_section
                .iter()
                .find(|(k, _)| k == key)
                .map(|&(_, v)| v)
                .expect("forward section measured above")
        };
        let cost1 = 1.0 / lookup("fused_int8_b1_seq_per_s").max(1e-9);
        let cost16 = 16.0 / lookup("fused_int8_b16_seq_per_s").max(1e-9);
        let per_req_s = ((cost16 - cost1) / 15.0).max(1e-7);
        let service =
            ServiceModel { base_s: (cost1 - per_req_s).max(0.0), per_req_s, simulate: true };
        let workers = 2usize;
        let capacity = workers as f64 * service.capacity_rps(16);
        println!(
            "  capacity sweep: modeled cost(b=16) {:.2}ms -> {:.0} req/s across {workers} workers",
            service.cost_s(16) * 1e3,
            capacity
        );

        // SLOs scale with the modeled batch cost so the sweep stresses the
        // scheduler identically on fast and slow machines
        let mut registry = Registry::new();
        let tight_s = (3.0 * service.cost_s(16)).max(0.010);
        let relaxed_s = (10.0 * service.cost_s(16)).max(0.050);
        registry.add_with_slo("interactive", &qm, &dev, Some(Duration::from_secs_f64(tight_s)));
        registry.add_with_slo("standard", &qm, &dev, Some(Duration::from_secs_f64(relaxed_s)));
        registry.add("batch", &qm, &dev);
        let deadline = Duration::from_secs_f64((20.0 * service.cost_s(16)).max(0.2));

        let n = 4000usize;
        let mults = [0.25, 0.5, 0.75, 0.9, 1.1, 1.5, 2.0];
        let mut curve_rows: Vec<Json> = Vec::new();
        let mut table_rows = Vec::new();
        let mut edf_delta_at_overload = 0.0;
        for (mi, &mult) in mults.iter().enumerate() {
            let rate = capacity * mult;
            let trace = TraceGenerator::heavy_tailed(rate).generate_tagged(
                n,
                &registry.sample_counts(),
                0xCA9A + mi as u64,
            );
            let mut att = [0.0f64; 2];
            for (pi, sched) in [SchedPolicy::Fifo, SchedPolicy::Edf].into_iter().enumerate() {
                let scfg = ServerConfig {
                    max_batch: 16,
                    max_wait: Duration::from_millis(4),
                    queue_cap: 512,
                    workers,
                    deadline: Some(deadline),
                    sched,
                    service: Some(service),
                    clock: Clock::virt(),
                    ..ServerConfig::default()
                };
                let s = serve(&registry, &trace, &scfg).expect("capacity serve");
                att[pi] = s.slo_attainment;
                curve_rows.push(capacity_row(mult, rate, sched, &s));
                table_rows.push(vec![
                    format!("{mult:.2}"),
                    format!("{rate:.0}"),
                    sched.to_string(),
                    format!("{:.1}", s.p50_ms),
                    format!("{:.1}", s.p99_ms),
                    format!("{:.3}", s.shed as f64 / s.offered.max(1) as f64),
                    format!("{:.3}", s.expired as f64 / s.offered.max(1) as f64),
                    format!("{:.3}", s.slo_attainment),
                ]);
            }
            if mult == 1.1 {
                edf_delta_at_overload = att[1] - att[0];
            }
        }
        b.table(
            "capacity curves (heavy-tailed trace, simulated service, virtual clock)",
            ["load x", "offered rps", "sched", "p50 ms", "p99 ms", "shed", "expired", "SLO att"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            table_rows,
        );

        // one chaos point at 0.9x load under EDF: a worker dies mid-drain
        // and respawns, then a storm overwhelms admission — serve() itself
        // enforces the conservation law, so this row doubles as an
        // end-to-end chaos check on the real bench model
        let chaos_row = {
            let rate = capacity * 0.9;
            let span = n as f64 / rate.max(1e-9);
            let plan = ChaosPlan::new()
                .kill_at(span * 0.25)
                .respawn_at(span * 0.30)
                .storm_at(span * 0.50, n / 8, 0);
            let trace = TraceGenerator::heavy_tailed(rate).generate_tagged(
                n,
                &registry.sample_counts(),
                0xC405,
            );
            let scfg = ServerConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(4),
                queue_cap: 512,
                workers,
                deadline: Some(deadline),
                sched: SchedPolicy::Edf,
                service: Some(service),
                chaos: Some(plan),
                clock: Clock::virt(),
                ..ServerConfig::default()
            };
            let s = serve(&registry, &trace, &scfg).expect("chaos serve");
            println!(
                "  chaos point: {} offered ({} injected), {} kill / {} respawn, \
                 attainment {:.3}",
                s.offered, s.injected, s.worker_kills, s.worker_respawns, s.slo_attainment
            );
            capacity_row(0.9, rate, SchedPolicy::Edf, &s)
        };

        let tenants_json: Vec<Json> = registry
            .names()
            .iter()
            .zip(registry.slos_s())
            .map(|(name, slo)| {
                Json::object(vec![
                    ("name".to_string(), Json::from(name.as_str())),
                    (
                        "slo_ms".to_string(),
                        slo.map(|s| Json::from(s * 1e3)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        let doc = Json::object(vec![
            ("bench".to_string(), Json::from("engine_inference")),
            ("source".to_string(), Json::from(source)),
            (
                "service_model".to_string(),
                Json::object(vec![
                    ("base_ms".to_string(), Json::from(service.base_s * 1e3)),
                    ("per_req_ms".to_string(), Json::from(service.per_req_s * 1e3)),
                    ("workers".to_string(), Json::from(workers)),
                    ("capacity_rps".to_string(), Json::from(capacity)),
                ]),
            ),
            ("tenants".to_string(), Json::Array(tenants_json)),
            ("requests_per_point".to_string(), Json::from(n)),
            ("curves".to_string(), Json::Array(curve_rows)),
            ("chaos_point".to_string(), chaos_row),
            (
                "edf_minus_fifo_attainment_at_1p1x".to_string(),
                Json::from(edf_delta_at_overload),
            ),
        ]);
        let path = std::path::Path::new("results/capacity.json");
        let _ = std::fs::create_dir_all("results");
        match std::fs::write(path, doc.pretty()) {
            Ok(()) => println!("  capacity curves -> {}", path.display()),
            Err(e) => svdquant::log_warn!("bench", "could not write {}: {e}", path.display()),
        }
        Json::object(vec![
            ("path".to_string(), Json::from("results/capacity.json")),
            (
                "edf_minus_fifo_attainment_at_1p1x".to_string(),
                Json::from(edf_delta_at_overload),
            ),
        ])
    };

    // ---- artifact cold start: pipeline-from-scratch vs mmap load ---------
    // quantize-once/serve-many: the deployed model goes to a QTZ2 artifact,
    // then cold start (fresh process wants to serve its first request) is
    // measured both ways — full score→select→pack pipeline vs artifact
    // open+load — each including the first fused forward. The loaded
    // model's logits must be bitwise identical to the in-memory model's.
    qm.set_kernel(GemmKernel::Int8);
    let art_path = std::path::PathBuf::from("results/bench_model.qtz2");
    svdquant::artifact::write_artifact(&art_path, &qm, Json::from("engine_inference bench"))
        .expect("write artifact");
    let (cold_ids, cold_mask) = dev.batch_slices(0, 8);
    let reference = qm.forward_fused(&cold_ids, &cold_mask).expect("reference fwd");

    let t0 = std::time::Instant::now();
    let qm_cold = QuantizePipeline::for_checkpoint(&cfg, &ckpt)
        .budget(256)
        .quant(qcfg)
        .build()
        .expect("cold pipeline")
        .deploy(256)
        .expect("cold deploy");
    let out_pipe = qm_cold.forward_fused(&cold_ids, &cold_mask).expect("cold fwd");
    let pipeline_cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(out_pipe.max_abs_diff(&reference), 0.0, "pipeline redeploy must be deterministic");

    let t0 = std::time::Instant::now();
    let qa = svdquant::artifact::QuantizedArtifact::open(&art_path).expect("open artifact");
    let qm_art = qa.load_model().expect("load model");
    let out_art = qm_art.forward_fused(&cold_ids, &cold_mask).expect("artifact fwd");
    let artifact_cold_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        out_art.max_abs_diff(&reference),
        0.0,
        "artifact-loaded model must match the in-memory model bit for bit"
    );
    println!(
        "  cold start to first logits: pipeline {:.1}ms vs artifact load {:.2}ms ({:.0}x, {})",
        pipeline_cold_s * 1e3,
        artifact_cold_s * 1e3,
        pipeline_cold_s / artifact_cold_s.max(1e-12),
        if qa.is_mapped() { "mmap" } else { "owned read" },
    );
    b.timeit("artifact open + load_model", || {
        svdquant::artifact::QuantizedArtifact::open(&art_path)
            .and_then(|qa| qa.load_model())
            .expect("reload")
    });

    // resident memory at 1 vs 4 workers loading from one artifact: each
    // worker owns only scales/overlay/shared-fp32; the packed code streams
    // are borrowed from one shared mapping, resident once per process
    let workers: Vec<QuantizedModel> = (0..4).map(|_| qa.load_model().expect("load")).collect();
    let (owned_1, shared_mapped) = workers[0].resident_split();
    let owned_4: usize = workers.iter().map(|m| m.resident_split().0).sum();
    let (in_mem_total, _) = {
        let (o, b2) = qm.resident_split();
        (o + b2, b2)
    };
    println!(
        "  resident: 1 worker {} owned + {} shared-mapped; 4 workers {} owned + {} \
         shared-mapped (4 in-process copies would be {})",
        svdquant::util::human_bytes(owned_1),
        svdquant::util::human_bytes(shared_mapped),
        svdquant::util::human_bytes(owned_4),
        svdquant::util::human_bytes(shared_mapped),
        svdquant::util::human_bytes(4 * in_mem_total),
    );
    if let Some(rss) = svdquant::util::resident_set_bytes() {
        println!("  process RSS with 4 artifact workers live: {}", svdquant::util::human_bytes(rss));
    }
    drop(workers);

    // ---- machine-readable trajectory -------------------------------------
    let fwd_json: Vec<(String, Json)> = fwd_section
        .into_iter()
        .map(|(k, v)| (k, Json::from(v)))
        .collect();
    common::write_bench_serving(
        "engine_inference",
        Json::object(vec![
            ("source".to_string(), Json::from(source)),
            ("forward".to_string(), Json::object(fwd_json)),
            ("forward_by_width".to_string(), Json::object(width_fwd)),
            ("simd_forward".to_string(), simd_fwd),
            ("serving".to_string(), Json::Array(json_rows)),
            ("obs".to_string(), obs_json),
            ("capacity".to_string(), capacity_json),
            (
                "virtual_replay".to_string(),
                Json::object(vec![
                    ("trace_span_s".to_string(), Json::from(vs.wall_s)),
                    ("real_wall_s".to_string(), Json::from(virt_wall_s)),
                    ("completions".to_string(), Json::from(vs.completions as f64)),
                ]),
            ),
            (
                "cold_start".to_string(),
                Json::object(vec![
                    ("pipeline_s".to_string(), Json::from(pipeline_cold_s)),
                    ("artifact_load_s".to_string(), Json::from(artifact_cold_s)),
                    (
                        "speedup".to_string(),
                        Json::from(pipeline_cold_s / artifact_cold_s.max(1e-12)),
                    ),
                    ("artifact_bytes".to_string(), Json::from(qa.file_bytes() as f64)),
                    ("mapped".to_string(), Json::from(qa.is_mapped())),
                    ("resident_owned_1_worker".to_string(), Json::from(owned_1 as f64)),
                    ("resident_owned_4_workers".to_string(), Json::from(owned_4 as f64)),
                    (
                        "resident_shared_mapped".to_string(),
                        Json::from(shared_mapped as f64),
                    ),
                ]),
            ),
        ]),
    );
    b.finish();
}

/// One point on the capacity curve — everything a load-vs-latency or
/// SLO-attainment plot needs, per scheduling policy.
fn capacity_row(mult: f64, rate: f64, sched: SchedPolicy, s: &ServeStats) -> Json {
    let offered = s.offered.max(1) as f64;
    Json::object(vec![
        ("load_multiple".to_string(), Json::from(mult)),
        ("offered_rps".to_string(), Json::from(rate)),
        ("sched".to_string(), Json::from(sched.to_string())),
        ("achieved_rps".to_string(), Json::from(s.throughput_rps)),
        ("p50_ms".to_string(), Json::from(s.p50_ms)),
        ("p99_ms".to_string(), Json::from(s.p99_ms)),
        ("shed_rate".to_string(), Json::from(s.shed as f64 / offered)),
        ("expired_rate".to_string(), Json::from(s.expired as f64 / offered)),
        ("slo_attainment".to_string(), Json::from(s.slo_attainment)),
        ("expired_wait_p99_ms".to_string(), Json::from(s.expired_wait_p99_ms)),
        ("injected".to_string(), Json::from(s.injected)),
        ("worker_kills".to_string(), Json::from(s.worker_kills)),
        ("worker_respawns".to_string(), Json::from(s.worker_respawns)),
        (
            "per_tenant".to_string(),
            Json::Array(
                s.per_tenant
                    .iter()
                    .map(|t| {
                        Json::object(vec![
                            ("task".to_string(), Json::from(t.task.as_str())),
                            (
                                "slo_ms".to_string(),
                                t.slo_ms.map(Json::from).unwrap_or(Json::Null),
                            ),
                            ("slo_attainment".to_string(), Json::from(t.slo_attainment)),
                            ("completions".to_string(), Json::from(t.completions)),
                            ("shed".to_string(), Json::from(t.shed)),
                            ("expired".to_string(), Json::from(t.expired)),
                            ("p99_ms".to_string(), Json::from(t.p99_ms)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn serve_stats_json(
    kernel: &str,
    threads: usize,
    workers: usize,
    s: &ServeStats,
    tokens_s: f64,
) -> Json {
    Json::object(vec![
        ("kernel".to_string(), Json::from(kernel)),
        ("threads".to_string(), Json::from(threads as f64)),
        ("workers".to_string(), Json::from(workers as f64)),
        ("rps".to_string(), Json::from(s.throughput_rps)),
        ("tokens_per_s".to_string(), Json::from(tokens_s)),
        ("p50_ms".to_string(), Json::from(s.p50_ms)),
        ("p95_ms".to_string(), Json::from(s.p95_ms)),
        ("p99_ms".to_string(), Json::from(s.p99_ms)),
        ("mean_batch".to_string(), Json::from(s.mean_batch)),
        ("shed".to_string(), Json::from(s.shed as f64)),
        ("expired".to_string(), Json::from(s.expired as f64)),
        ("accuracy".to_string(), Json::from(s.accuracy)),
        ("completions".to_string(), Json::from(s.completions as f64)),
    ])
}
