//! Regenerates paper Table I (MRPC accuracy recovery vs protection budget)
//! and prints paper-vs-measured rows. `harness = false`.
#[path = "common/mod.rs"]
mod common;

fn main() {
    // paper Table I rows: (k, AWQ, SpQR, SVD)
    let paper = [
        (1usize, 0.8505, 0.8480, 0.8554),
        (16, 0.8505, 0.8456, 0.8554),
        (64, 0.8529, 0.8480, 0.8529),
        (256, 0.8529, 0.8480, 0.8529),
        (1024, 0.8505, 0.8480, 0.8529),
        (4096, 0.8529, 0.8480, 0.8529),
    ];
    common::table_bench("table1_mrpc", "mrpc", &paper);
}
