//! Regenerates paper Table III (QNLI accuracy recovery). The paper reports
//! k ∈ {1, 256, 4096}; we run the full grid and compare at those points.
//! `harness = false`.
#[path = "common/mod.rs"]
mod common;

fn main() {
    // paper Table III rows: (k, AWQ, SpQR, SVD)
    let paper = [
        (1usize, 0.8803, 0.8805, 0.8788),
        (256, 0.8775, 0.8803, 0.8836),
        (4096, 0.8817, 0.8845, 0.8834),
    ];
    common::table_bench("table3_qnli", "qnli", &paper);
}
