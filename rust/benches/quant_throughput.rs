//! §III-B mechanism benches: quantize/dequantize/pack bandwidth, the fused
//! mixed-precision matvec, the integer-domain igemm vs float-path GEMM
//! (1-vs-N threads), and the clip/bits/NF4 ablations (DESIGN.md §5, §8).
//! `harness = false`.

#[path = "common/mod.rs"]
mod common;

use svdquant::json::Json;
use svdquant::linalg::Matrix;
use svdquant::quant::nf4::nf4_fake_quant;
use svdquant::quant::symmetric::mse;
use svdquant::quant::{
    dequantize, fake_quant, pack_nibbles, quant_params, quantize_codes, quantize_rows,
    unpack_nibbles, BitPack, QuantConfig, QuantizedMatrix, SUPPORTED_BITS,
};
use svdquant::sparse::Coo;
use svdquant::util::bench::Bench;
use svdquant::util::pool;
use svdquant::util::rng::Rng;
use svdquant::util::simd::{self, Isa};

fn main() {
    let mut b = Bench::new("quant_throughput");
    let mut rng = Rng::new(0x0B17);
    let (rows, cols) = (1024usize, 1024usize);
    let mut w = Matrix::zeros(rows, cols);
    rng.fill_normal(w.data_mut(), 0.05);
    let bytes = (rows * cols * 4) as f64;
    let cfg = QuantConfig::default();

    let p = quant_params(&w, &cfg);
    let codes = quantize_codes(&w, &p);
    let packed = pack_nibbles(&codes);

    b.timeit_throughput("quant_params 1024² (std+max scan)", bytes, "B", || {
        quant_params(&w, &cfg)
    });
    b.timeit_throughput("quantize_codes 1024²", bytes, "B", || {
        quantize_codes(&w, &p)
    });
    b.timeit_throughput("dequantize 1024²", bytes, "B", || {
        dequantize(&codes, &p, rows, cols)
    });
    b.timeit_throughput("pack_nibbles 1024²", (rows * cols) as f64, "codes", || {
        pack_nibbles(&codes)
    });
    b.timeit_throughput("unpack_nibbles 1024²", (rows * cols) as f64, "codes", || {
        unpack_nibbles(&packed, rows * cols)
    });
    b.timeit_throughput("fake_quant 1024² end-to-end", bytes, "B", || {
        fake_quant(&w, &cfg)
    });

    // --- BitPack codec bandwidth per supported width ----------------------
    // codes are requantized per width so every value is in the codec's
    // range; 3-bit is the interesting row (codes straddle byte boundaries).
    // Each width also records decode bandwidth on the pre-PR7 bit-serial
    // walk vs the dispatched fast arm (SIMD nibble expand at 4 bits,
    // unrolled loops at 2/3, byte copy at 8) for the `simd` JSON section.
    let mut decode_json: Vec<(String, Json)> = Vec::new();
    for bits in SUPPORTED_BITS {
        let wcfg = cfg.with_bits(bits);
        let wp = quant_params(&w, &wcfg);
        let wcodes = quantize_codes(&w, &wp);
        let codec = BitPack::new(bits).unwrap();
        let wpacked = codec.pack(&wcodes);
        b.timeit_throughput(
            &format!("BitPack({bits}) pack 1024²"),
            (rows * cols) as f64,
            "codes",
            || codec.pack(&wcodes),
        );
        b.timeit_throughput(
            &format!("BitPack({bits}) unpack 1024²"),
            (rows * cols) as f64,
            "codes",
            || codec.unpack(&wpacked, rows * cols),
        );
        let n = rows * cols;
        let mut dec = vec![0i8; n];
        b.timeit_throughput(
            &format!("BitPack({bits}) unpack_into serial (before)"),
            n as f64,
            "codes",
            || codec.unpack_into_serial(&wpacked, &mut dec),
        );
        b.timeit_throughput(
            &format!("BitPack({bits}) unpack_into fast arm"),
            n as f64,
            "codes",
            || codec.unpack_into(&wpacked, &mut dec),
        );
        let serial_cs = common::measure_units_per_s(n as f64, 100, || {
            codec.unpack_into_serial(&wpacked, &mut dec)
        });
        let fast_cs = common::measure_units_per_s(n as f64, 100, || {
            codec.unpack_into(&wpacked, &mut dec)
        });
        decode_json.push((format!("b{bits}_serial_mcodes_s"), Json::from(serial_cs / 1e6)));
        decode_json.push((format!("b{bits}_fast_mcodes_s"), Json::from(fast_cs / 1e6)));
    }

    // fused mixed-precision matvec vs dense f32 matvec
    let mut sal = Coo::new(rows, cols);
    for idx in Rng::new(7).sample_distinct(rows * cols, 4096) {
        sal.push(idx / cols, idx % cols, w[(idx / cols, idx % cols)]);
    }
    let qm = QuantizedMatrix::from_dense(&w, &cfg, &sal);
    let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0.0f32; rows];
    let flops = (2 * rows * cols) as f64;
    b.timeit_throughput("qmatvec packed+salient 1024² (LUT)", flops, "flop", || {
        qm.matvec(&x, &mut y)
    });
    // the pre-optimization baseline (EXPERIMENTS.md §Perf L3): unpack the
    // row into a scratch buffer with scalar shift/sign-extend, then dot
    let mut scratch = vec![0i8; cols];
    b.timeit_throughput("qmatvec naive unpack (before)", flops, "flop", || {
        for i in 0..rows {
            let row_packed = &packed[0..(cols + 1) / 2]; // same bytes/row layout
            for (j, s) in scratch.iter_mut().enumerate() {
                *s = svdquant::quant::packing::unpack_at(row_packed, j);
            }
            y[i] = scratch
                .iter()
                .zip(&x)
                .map(|(&c, &xv)| c as f32 * xv)
                .sum::<f32>()
                * p.scales[0];
        }
    });
    let dense = qm.dequantize_dense();
    b.timeit_throughput("dense f32 matvec 1024² (reference)", flops, "flop", || {
        let mut acc = vec![0.0f32; rows];
        for i in 0..rows {
            acc[i] = svdquant::linalg::matmul::dot(dense.row(i), &x, cols);
        }
        acc
    });

    // --- batch GEMM: float path vs integer-domain igemm -------------------
    // the serving-hot-path comparison (DESIGN.md §8): per-(row,request)
    // float decode (the pre-PR2 baseline) vs batch-panel-blocked float
    // decode vs int4×int8→i32 igemm, at 1-vs-N threads
    let batch = 16usize;
    let mut xb = Matrix::zeros(batch, cols);
    rng.fill_normal(xb.data_mut(), 1.0);
    let gflops = (2 * rows * cols * batch) as f64;
    let mut yb = vec![0.0f32; rows];
    b.timeit_throughput("matmul_xt b=16 per-request matvec (before)", gflops, "flop", || {
        for r in 0..batch {
            qm.matvec(xb.row(r), &mut yb);
        }
    });
    let mut igemm_json: Vec<(String, Json)> = Vec::new();
    // the float batch-panel path is a serial kernel — measure it once
    b.timeit_throughput("matmul_xt b=16 float batch-panel (serial)", gflops, "flop", || {
        qm.matmul_xt(&xb)
    });
    igemm_json.push((
        "float_gflop_s".to_string(),
        Json::from(common::measure_units_per_s(gflops, 150, || qm.matmul_xt(&xb)) / 1e9),
    ));
    // the igemm path fans weight-row panels over the pool: 1-vs-N threads
    for &threads in &[1usize, 0] {
        pool::set_global_parallelism(threads);
        let label = if threads == 1 {
            "1 thread".to_string()
        } else {
            format!("{} threads", pool::global_parallelism())
        };
        b.timeit_throughput(
            &format!("matmul_xt b=16 int8 igemm ({label})"),
            gflops,
            "flop",
            || qm.matmul_xt_int(&xb),
        );
        let tkey = if threads == 1 { "t1" } else { "tN" };
        let gflop_s = common::measure_units_per_s(gflops, 150, || qm.matmul_xt_int(&xb)) / 1e9;
        igemm_json.push((format!("int8_{tkey}_gflop_s"), Json::from(gflop_s)));
    }
    pool::set_global_parallelism(0);

    // --- igemm per residual width (the mixed-precision serving axis) ------
    // one row per supported width at N threads: 4-bit runs the SIMD nibble
    // expand, 2/3 the unrolled multi-code decoders, 8 a byte copy — the
    // spread between them is the price of a width the allocator assigns
    let mut width_json: Vec<(String, Json)> = Vec::new();
    for bits in SUPPORTED_BITS {
        let qm_b = QuantizedMatrix::from_dense(&w, &cfg.with_bits(bits), &sal);
        b.timeit_throughput(
            &format!("matmul_xt b=16 int8 igemm ({bits}-bit codes)"),
            gflops,
            "flop",
            || qm_b.matmul_xt_int(&xb),
        );
        let gflop_s = common::measure_units_per_s(gflops, 150, || qm_b.matmul_xt_int(&xb)) / 1e9;
        width_json.push((format!("int8_b{bits}_gflop_s"), Json::from(gflop_s)));
    }

    // --- scalar vs SIMD dispatch (ROADMAP acceptance metric) --------------
    // single-thread igemm with the dispatch forced scalar vs the resolved
    // hardware arm — outputs are bitwise-identical (rust/tests/simd.rs),
    // so this isolates the kernel speedup from any numerical drift;
    // target ≥2× on AVX2 hosts
    let mut simd_json: Vec<(String, Json)> = Vec::new();
    simd_json.push(("kernel_isa".to_string(), Json::from(simd::active_isa().name())));
    pool::set_global_parallelism(1);
    let scalar_t1 = {
        let _g = simd::override_isa(Isa::Scalar);
        b.timeit_throughput("matmul_xt b=16 int8 igemm t1 (forced scalar)", gflops, "flop", || {
            qm.matmul_xt_int(&xb)
        });
        common::measure_units_per_s(gflops, 200, || qm.matmul_xt_int(&xb)) / 1e9
    };
    let simd_t1 = {
        let label = format!("matmul_xt b=16 int8 igemm t1 ({})", simd::active_isa().name());
        b.timeit_throughput(&label, gflops, "flop", || qm.matmul_xt_int(&xb));
        common::measure_units_per_s(gflops, 200, || qm.matmul_xt_int(&xb)) / 1e9
    };
    pool::set_global_parallelism(0);
    simd_json.push(("int8_t1_scalar_gflop_s".to_string(), Json::from(scalar_t1)));
    simd_json.push(("int8_t1_simd_gflop_s".to_string(), Json::from(simd_t1)));
    simd_json.push(("simd_speedup_t1".to_string(), Json::from(simd_t1 / scalar_t1.max(1e-12))));

    let elems = (batch * cols) as f64;
    b.timeit_throughput("quantize_rows b=16 (dynamic int8 activations)", elems, "elem", || {
        quantize_rows(&xb)
    });
    let q_scalar = {
        let _g = simd::override_isa(Isa::Scalar);
        b.timeit_throughput("quantize_rows b=16 (forced scalar)", elems, "elem", || {
            quantize_rows(&xb)
        });
        common::measure_units_per_s(elems, 100, || quantize_rows(&xb))
    };
    let q_simd = common::measure_units_per_s(elems, 100, || quantize_rows(&xb));
    simd_json.push(("quantize_rows_scalar_melem_s".to_string(), Json::from(q_scalar / 1e6)));
    simd_json.push(("quantize_rows_simd_melem_s".to_string(), Json::from(q_simd / 1e6)));
    simd_json.push(("decode_by_width".to_string(), Json::object(decode_json)));

    common::write_bench_serving(
        "quant_throughput",
        Json::object(vec![
            ("igemm_1024_b16".to_string(), Json::object(igemm_json)),
            ("igemm_by_width".to_string(), Json::object(width_json)),
            ("simd".to_string(), Json::object(simd_json)),
        ]),
    );

    // --- ablations: quantization error by config --------------------------
    let mut rows_t = Vec::new();
    for (name, cfg) in [
        ("int4 clip=2.5 (paper)", QuantConfig { bits: 4, clip_sigma: Some(2.5), per_row: false }),
        ("int4 no clip", QuantConfig { bits: 4, clip_sigma: None, per_row: false }),
        ("int4 clip=3.5", QuantConfig { bits: 4, clip_sigma: Some(3.5), per_row: false }),
        ("int4 per-row", QuantConfig { bits: 4, clip_sigma: Some(2.5), per_row: true }),
        ("int3 clip=2.5", QuantConfig { bits: 3, clip_sigma: Some(2.5), per_row: false }),
        ("int8 clip=2.5", QuantConfig { bits: 8, clip_sigma: Some(2.5), per_row: false }),
    ] {
        let wq = fake_quant(&w, &cfg);
        rows_t.push(vec![name.to_string(), format!("{:.3e}", mse(&w, &wq))]);
    }
    // matrices with outliers show why clipping matters
    let mut wo = w.clone();
    for idx in Rng::new(9).sample_distinct(rows * cols, 16) {
        wo.data_mut()[idx] = if idx % 2 == 0 { 1.5 } else { -1.5 };
    }
    rows_t.push(vec![
        "int4 clip=2.5 + outliers".into(),
        format!("{:.3e}", mse(&wo, &fake_quant(&wo, &QuantConfig::default()))),
    ]);
    rows_t.push(vec![
        "int4 no-clip + outliers".into(),
        format!(
            "{:.3e}",
            mse(&wo, &fake_quant(&wo, &QuantConfig { clip_sigma: None, ..QuantConfig::default() }))
        ),
    ]);
    rows_t.push(vec![
        "nf4 per-row (ablation)".into(),
        format!("{:.3e}", mse(&w, &nf4_fake_quant(&w))),
    ]);
    b.table(
        "quantization MSE ablation (1024², gaussian weights σ=0.05)",
        vec!["config".into(), "MSE".into()],
        rows_t,
    );
    b.finish();
}
