//! Regenerates paper Fig. 2: IoU of the SVD-selected weight indices vs the
//! AWQ and SpQR selections, per protection budget, aggregated over all
//! quantizable layers of every task. The paper's qualitative claim — high
//! overlap with SpQR (~60-70% at low k), lower with AWQ (~30%) — is what
//! the shape check rows record. `harness = false`.

#[path = "common/mod.rs"]
mod common;

use svdquant::calib::CalibStats;
use svdquant::coordinator::{score_layer, PreserveSpec};
use svdquant::model::Engine;
use svdquant::report;
use svdquant::saliency::{iou, select_topk, Method};
use svdquant::util::bench::Bench;

fn main() {
    let Some(art) = common::artifacts_or_skip("fig2_overlap") else { return };
    let mut b = Bench::new("fig2_overlap").quick();
    let mut results = svdquant::coordinator::sweep::SweepResults::default();
    let budgets = art.budgets();

    for task in art.tasks() {
        let ckpt = art.checkpoint(&task).expect("ckpt");
        let calib_data = art.dataset(&task, "calib").expect("calib data");
        let engine = Engine::new(art.model_cfg, ckpt).expect("engine");
        let calib =
            CalibStats::collect(&engine, &calib_data, art.calib_samples(), 16).expect("calib");
        let ckpt = engine.params();
        for name in art.model_cfg.quantizable_names() {
            let w = ckpt.get(&name).unwrap();
            let svd = score_layer(
                &name,
                w,
                &PreserveSpec { method: Method::Svd, ..Default::default() },
                None,
            )
            .unwrap();
            let awq = score_layer(
                &name,
                w,
                &PreserveSpec { method: Method::Awq, ..Default::default() },
                Some(&calib),
            )
            .unwrap();
            let spqr = score_layer(
                &name,
                w,
                &PreserveSpec {
                    method: Method::Spqr,
                    spqr_damp: art.spqr_damp(),
                    ..Default::default()
                },
                Some(&calib),
            )
            .unwrap();
            for &k in &budgets {
                let s = select_topk(&svd, k);
                results.overlap.record("awq", k, iou(&s, &select_topk(&awq, k)));
                results.overlap.record("spqr", k, iou(&s, &select_topk(&spqr, k)));
            }
        }
    }

    let chart = report::fig2_chart(&results);
    println!("{chart}");
    std::fs::create_dir_all("results/figures").ok();
    std::fs::write("results/figures/fig2_overlap.txt", &chart).ok();

    let mut rows = Vec::new();
    for &k in &budgets {
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", results.overlap.mean("awq", k).unwrap_or(0.0)),
            format!("{:.3}", results.overlap.mean("spqr", k).unwrap_or(0.0)),
        ]);
    }
    b.table(
        "Fig.2 IoU summary (paper: awq ≈ 0.30, spqr ≈ 0.60-0.70 at low k)",
        vec!["k".into(), "IoU vs AWQ".into(), "IoU vs SpQR".into()],
        rows,
    );
    b.finish();
}
