//! Regenerates paper Fig. 2: IoU of the SVD-selected weight indices vs the
//! AWQ and SpQR selections, per protection budget, aggregated over all
//! quantizable layers of every task. The paper's qualitative claim — high
//! overlap with SpQR (~60-70% at low k), lower with AWQ (~30%) — is what
//! the shape check rows record.
//!
//! Runs through one `QuantizePipeline` per task: each scorer's maps are
//! computed once (layer-parallel) and every budget reuses them from the
//! pipeline cache. `harness = false`.

#[path = "common/mod.rs"]
mod common;

use svdquant::calib::CalibStats;
use svdquant::coordinator::QuantizePipeline;
use svdquant::model::Engine;
use svdquant::report;
use svdquant::saliency::{record_selection_overlaps, resolve_scorer, SelectionGrid};
use svdquant::util::bench::Bench;

fn main() {
    let Some(art) = common::artifacts_or_skip("fig2_overlap") else { return };
    let mut b = Bench::new("fig2_overlap").quick();
    let mut results = svdquant::coordinator::sweep::SweepResults::default();
    let budgets = art.budgets();
    let sparams = art.scorer_params();

    for task in art.tasks() {
        let ckpt = art.checkpoint(&task).expect("ckpt");
        let calib_data = art.dataset(&task, "calib").expect("calib data");
        let engine = Engine::new(art.model_cfg, ckpt).expect("engine");
        let calib =
            CalibStats::collect(&engine, &calib_data, art.calib_samples(), 16).expect("calib");
        let ckpt = engine.params();
        let mut pipe = QuantizePipeline::for_checkpoint(&art.model_cfg, ckpt)
            .calib(Some(&calib))
            .build()
            .expect("pipeline");
        let mut sels = SelectionGrid::new();
        for m in ["svd", "awq", "spqr"] {
            pipe.set_scorer(resolve_scorer(m, &sparams).expect("scorer")).expect("set scorer");
            for &k in &budgets {
                sels.insert((m.to_string(), k), pipe.select(k).expect("select"));
            }
        }
        record_selection_overlaps(&mut results.overlap, &sels, &budgets, "svd", &["awq", "spqr"]);
    }

    let chart = report::fig2_chart(&results);
    println!("{chart}");
    std::fs::create_dir_all("results/figures").ok();
    std::fs::write("results/figures/fig2_overlap.txt", &chart).ok();

    let mut rows = Vec::new();
    for &k in &budgets {
        rows.push(vec![
            k.to_string(),
            format!("{:.3}", results.overlap.mean("awq", k).unwrap_or(0.0)),
            format!("{:.3}", results.overlap.mean("spqr", k).unwrap_or(0.0)),
        ]);
    }
    b.table(
        "Fig.2 IoU summary (paper: awq ≈ 0.30, spqr ≈ 0.60-0.70 at low k)",
        vec!["k".into(), "IoU vs AWQ".into(), "IoU vs SpQR".into()],
        rows,
    );
    b.finish();
}
