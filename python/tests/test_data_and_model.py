"""Data generators, tensorfile container, model forward, and the
jnp↔pallas model parity (the L1-inside-L2 composition proof)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data as dm
from compile import tensorfile
from compile.config import MODEL, TASKS
from compile.model import (
    forward, init_params, loss_fn, param_names, quantizable_names,
)

# ------------------------------------------------------------------- data


@pytest.mark.parametrize("task", list(TASKS))
def test_split_shapes_and_determinism(task):
    a = dm.generate_split(TASKS[task], "dev")
    b = dm.generate_split(TASKS[task], "dev")
    assert a.input_ids.shape == (TASKS[task].n_dev, MODEL.max_len)
    np.testing.assert_array_equal(a.input_ids, b.input_ids)
    np.testing.assert_array_equal(a.labels, b.labels)


@pytest.mark.parametrize("task", list(TASKS))
def test_labels_roughly_balanced(task):
    s = dm.generate_split(TASKS[task], "dev")
    bal = s.labels.mean()
    assert 0.38 < bal < 0.62, f"label balance {bal}"


def test_splits_are_distinct():
    tr = dm.generate_split(TASKS["rte"], "train")
    dv = dm.generate_split(TASKS["rte"], "dev")
    assert not np.array_equal(tr.input_ids[: dv.input_ids.shape[0]], dv.input_ids)


def test_token_ranges_valid():
    for task in TASKS:
        s = dm.generate_split(TASKS[task], "calib")
        assert s.input_ids.min() >= 0
        assert s.input_ids.max() < MODEL.vocab_size
        # CLS always first, mask covers it
        assert (s.input_ids[:, 0] == dm.CLS).all()
        assert (s.attention_mask[:, 0] == 1).all()
        # mask is a prefix (no holes)
        diffs = np.diff(s.attention_mask, axis=1)
        assert (diffs <= 0).all()


def _polarity_margin(tokens):
    """(#positive-synset tokens) − (#negative-synset tokens)."""
    syn = [(int(t) - dm.SYN_BASE) // dm.SYNSET_SIZE
           for t in tokens if dm.SYN_BASE <= t < dm.ENT_BASE]
    pos = sum(1 for s in syn if s < dm.POS_SYNSETS)
    return pos - (len(syn) - pos)


@pytest.mark.parametrize(
    "gen,margins,strip_prefix",
    [
        (dm._mrpc_example, {1, 2, 4}, False),
        (dm._rte_example, {1}, False),
        (dm._qnli_example, {1, 3, 3}, True),
    ],
)
def test_majority_semantics(gen, margins, strip_prefix):
    # label == sign of the latent polarity margin, margin magnitude from
    # the task's knob set
    rng = np.random.default_rng(123)
    for _ in range(60):
        a, b, label = gen(rng)
        if strip_prefix:
            assert dm.QTY_BASE <= a[0] < dm.FIL_BASE
            a = a[1:]
        m = _polarity_margin(np.concatenate([a, b]))
        assert abs(m) in margins, m
        assert (m > 0) == (label == 1)


def test_difficulty_ordering_of_margins():
    # difficulty ∝ margin-per-token (how strongly the mean latent polarity
    # separates the classes): rte hardest < mrpc < qnli easiest
    rng = np.random.default_rng(7)

    def mean_margin_ratio(gen, strip):
        ms = []
        for _ in range(300):
            a, b, _ = gen(rng)
            if strip:
                a = a[1:]
            n = len(a) + len(b)
            ms.append(abs(_polarity_margin(np.concatenate([a, b]))) / n)
        return float(np.mean(ms))

    m_rte = mean_margin_ratio(dm._rte_example, False)
    m_mrpc = mean_margin_ratio(dm._mrpc_example, False)
    m_qnli = mean_margin_ratio(dm._qnli_example, True)
    assert m_rte < m_mrpc < m_qnli, (m_rte, m_mrpc, m_qnli)


# -------------------------------------------------------------- tensorfile


def test_tensorfile_roundtrip(tmp_path):
    path = str(tmp_path / "t.qtz")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([1, -2, 3], dtype=np.int32),
        "c": np.array([7], dtype=np.uint8),
    }
    tensorfile.write(path, tensors, meta={"task": "x", "n": 3})
    back, meta = tensorfile.read(path)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype
    assert meta == {"task": "x", "n": 3}


def test_tensorfile_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.qtz"
    p.write_bytes(b"NOPE" + b"\0" * 16)
    with pytest.raises(ValueError):
        tensorfile.read(str(p))


def test_tensorfile_alignment(tmp_path):
    path = str(tmp_path / "a.qtz")
    tensorfile.write(path, {"x": np.ones(3, np.uint8), "y": np.ones(5, np.uint8)})
    back, _ = tensorfile.read(path)
    np.testing.assert_array_equal(back["y"], 1)


# ------------------------------------------------------------------ model


def tiny_batch(b=4):
    rng = np.random.default_rng(11)
    ids = rng.integers(4, 500, size=(b, MODEL.max_len)).astype(np.int32)
    ids[:, 0] = dm.CLS
    mask = np.ones((b, MODEL.max_len), np.int32)
    mask[:, 40:] = 0
    return jnp.asarray(ids), jnp.asarray(mask)


def test_param_names_cover_init():
    p = init_params(MODEL, 0)
    assert set(param_names(MODEL)) == set(p.keys())
    assert len(quantizable_names(MODEL)) == 6 * MODEL.layers + 2


def test_forward_shapes_and_grad():
    p = init_params(MODEL, 1)
    ids, mask = tiny_batch()
    logits = forward(p, ids, mask, MODEL)
    assert logits.shape == (4, MODEL.n_classes)
    labels = jnp.array([0, 1, 0, 1])
    (loss, acc), grads = jax.value_and_grad(
        lambda pp: loss_fn(pp, ids, mask, labels, MODEL), has_aux=True
    )(p)
    assert np.isfinite(float(loss))
    g = grads["layer0.wq"]
    assert float(jnp.abs(g).max()) > 0.0


def test_forward_pad_invariance():
    p = init_params(MODEL, 2)
    ids, mask = tiny_batch(2)
    a = forward(p, ids, mask, MODEL)
    ids2 = np.asarray(ids).copy()
    ids2[:, 40:] = 77  # garbage under the pad mask
    b = forward(p, jnp.asarray(ids2), mask, MODEL)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_pallas_path_matches_jnp_path():
    """The composition proof at python level: the model with Pallas
    attention + salient_matmul linears must match the plain-jnp model."""
    p = init_params(MODEL, 3)
    ids, mask = tiny_batch(2)
    a = forward(p, ids, mask, MODEL, use_pallas=False)
    b = forward(p, ids, mask, MODEL, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_loss_decreases_on_tiny_overfit():
    # 30 adam steps on one batch must reduce the loss (training sanity)
    import dataclasses

    from compile.train import _adam_step

    p = init_params(MODEL, 4)
    ids, mask = tiny_batch(8)
    labels = jnp.array([0, 1] * 4)
    grad_fn = jax.jit(
        jax.value_and_grad(lambda pp: loss_fn(pp, ids, mask, labels, MODEL), has_aux=True)
    )
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)
    (l0, _), g = grad_fn(p)
    for t in range(1, 31):
        (l, _), g = grad_fn(p)
        p, m, v = _adam_step(p, g, m, v, t, 3e-4)
    (l1, _), _ = grad_fn(p)
    assert float(l1) < float(l0) * 0.8, f"{float(l0)} -> {float(l1)}"
