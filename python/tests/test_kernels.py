"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes (hypothesis) — the CORE correctness signal of the
compile path."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.fake_quant import fake_quant
from compile.kernels.salient_matmul import salient_matmul
from compile.kernels.svd_score import svd_score

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# -------------------------------------------------------------- fake_quant


@given(
    m=st.integers(1, 200),
    n=st.integers(1, 300),
    bits=st.sampled_from([3, 4, 8]),
    seed=st.integers(0, 2**31),
)
def test_fake_quant_matches_ref(m, n, bits, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, m, n) * 0.05
    clip, scale = ref.quant_params(w, bits=bits)
    got = fake_quant(w, clip, scale, bits=bits)
    want = ref.fake_quant_ref(w, clip, scale, bits=bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_fake_quant_respects_block_boundaries():
    # shape deliberately not divisible by the block size
    rng = np.random.default_rng(0)
    w = rand(rng, 129, 257)
    clip, scale = ref.quant_params(w)
    got = fake_quant(w, clip, scale, block_m=64, block_n=64)
    want = ref.fake_quant_ref(w, clip, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_fake_quant_output_on_grid():
    rng = np.random.default_rng(1)
    w = rand(rng, 32, 32)
    clip, scale = ref.quant_params(w)
    got = np.asarray(fake_quant(w, clip, scale))
    codes = got / np.asarray(scale)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert np.abs(codes).max() <= 7 + 1e-4


def test_quant_params_zero_matrix():
    w = jnp.zeros((4, 4))
    clip, scale = ref.quant_params(w)
    assert float(scale) == 1.0
    out = ref.fake_quant_ref(w, clip, scale)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# --------------------------------------------------------------- svd_score


@given(
    dout=st.integers(1, 150),
    din=st.integers(1, 200),
    r=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31),
)
def test_svd_score_matches_factor_ref(dout, din, r, seed):
    rng = np.random.default_rng(seed)
    u = rand(rng, dout, r)
    s = jnp.abs(rand(rng, r)) + 0.1
    v = rand(rng, din, r)
    got = svd_score(u, s, v)
    want = ref.svd_score_from_factors_ref(u, s, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_svd_score_end_to_end_vs_full_svd():
    # factor via jnp SVD then feed the kernel; must equal ref.svd_score_ref
    rng = np.random.default_rng(2)
    w = rand(rng, 60, 90)
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    got = svd_score(u[:, :8], s[:8], vt[:8, :].T)
    want = ref.svd_score_ref(w, rank=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ----------------------------------------------------------- salient_matmul


@given(
    m=st.integers(1, 40),
    din=st.integers(1, 130),
    dout=st.integers(1, 90),
    density=st.floats(0.0, 0.2),
    seed=st.integers(0, 2**31),
)
def test_salient_matmul_matches_ref(m, din, dout, density, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, din)
    q = jnp.asarray(rng.integers(-7, 8, size=(dout, din)).astype(np.int8))
    scale = jnp.abs(rand(rng, dout)) * 0.1 + 1e-3
    mask = jnp.asarray((rng.random((dout, din)) < density).astype(np.float32))
    s_dense = rand(rng, dout, din) * mask
    got = salient_matmul(x, q, scale, s_dense, mask)
    want = ref.salient_matmul_ref(x, q, scale, s_dense, mask)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4
    )


def test_salient_matmul_identity_mask_is_dense_matmul():
    # mask all ones + s_dense = w → plain x @ w.T (the pallas-model path)
    rng = np.random.default_rng(3)
    x = rand(rng, 8, 32)
    w = rand(rng, 16, 32)
    q = jnp.zeros((16, 32), jnp.int8)
    scale = jnp.ones(16)
    mask = jnp.ones((16, 32))
    got = salient_matmul(x, q, scale, w, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w.T), atol=1e-4)


def test_salient_matmul_k_accumulation():
    # din spanning multiple k-blocks exercises the accumulator init logic
    rng = np.random.default_rng(4)
    x = rand(rng, 4, 600)
    q = jnp.asarray(rng.integers(-7, 8, size=(8, 600)).astype(np.int8))
    scale = jnp.ones(8) * 0.01
    mask = jnp.zeros((8, 600))
    s_dense = jnp.zeros((8, 600))
    got = salient_matmul(x, q, scale, s_dense, mask, block_k=128)
    want = ref.salient_matmul_ref(x, q, scale, s_dense, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


# ---------------------------------------------------------------- attention


@given(
    bh=st.integers(1, 6),
    s=st.sampled_from([4, 16, 48]),
    dh=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31),
)
def test_attention_matches_ref(bh, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, bh, s, dh)
    k = rand(rng, bh, s, dh)
    v = rand(rng, bh, s, dh)
    mask = jnp.asarray((rng.random((bh, s)) < 0.7).astype(np.float32))
    mask = mask.at[:, 0].set(1.0)  # at least one live token
    got = attention(q, k, v, mask)
    want = jnp.stack([ref.attention_ref(q[i], k[i], v[i], mask[i]) for i in range(bh)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_attention_fully_masked_keys_ignored():
    rng = np.random.default_rng(5)
    q = rand(rng, 1, 8, 16)
    k = rand(rng, 1, 8, 16)
    v = rand(rng, 1, 8, 16)
    mask = jnp.ones((1, 8)).at[0, 4:].set(0.0)
    base = np.asarray(attention(q, k, v, mask))
    # changing masked-out V rows must not change the output
    v2 = v.at[0, 4:].set(99.0)
    got = np.asarray(attention(q, k, v2, mask))
    np.testing.assert_allclose(got, base, atol=1e-5)


# ------------------------------------------------------------ score oracles


def test_topk_mask_selects_k():
    rng = np.random.default_rng(6)
    s = rand(rng, 10, 10)
    for k in [0, 1, 7, 100]:
        m = ref.topk_mask(s, k)
        assert int(np.asarray(m).sum()) == min(k, 100)


def test_preserve_keeps_salient_exact():
    rng = np.random.default_rng(7)
    w = rand(rng, 20, 20) * 0.05
    clip, scale = ref.quant_params(w)
    score = ref.svd_score_ref(w)
    mask = ref.topk_mask(score, 17)
    out = np.asarray(ref.preserve_ref(w, mask, clip, scale))
    wnp = np.asarray(w)
    mnp = np.asarray(mask)
    np.testing.assert_array_equal(out[mnp], wnp[mnp])
    assert not np.allclose(out[~mnp], wnp[~mnp])


def test_spqr_score_damping_keeps_finite():
    rng = np.random.default_rng(8)
    w = rand(rng, 6, 12)
    # rank-deficient activations (fewer rows than dims)
    x = rand(rng, 3, 12)
    xtx = x.T @ x
    s = np.asarray(ref.spqr_score_ref(w, xtx, 3))
    assert np.isfinite(s).all()
    assert (s >= 0).all()
