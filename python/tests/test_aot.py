"""AOT export path: HLO text emission, argument ordering, parity-vector
export. Does not train (uses random params)."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import tensorfile
from compile.aot import export_kernel_hlos, export_model_hlo, export_parity_vectors, to_hlo_text
from compile.config import MODEL
from compile.model import forward, init_params, param_names


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_model_hlo_export_has_all_args(tmp_path):
    p = init_params(MODEL, 0)
    out = str(tmp_path / "m.hlo.txt")
    export_model_hlo(p, MODEL, out, use_pallas=False, batch=8)
    text = open(out).read()
    assert "HloModule" in text
    # 2 data args + all params
    n_params = len(param_names(MODEL))
    # HLO text lists parameters as parameter(0..n)
    assert f"parameter({n_params + 1})" in text
    assert f"parameter({n_params + 2})" not in text


def test_pallas_model_hlo_differs(tmp_path):
    p = init_params(MODEL, 1)
    a = str(tmp_path / "a.hlo.txt")
    b = str(tmp_path / "b.hlo.txt")
    export_model_hlo(p, MODEL, a, use_pallas=False, batch=4)
    export_model_hlo(p, MODEL, b, use_pallas=True, batch=4)
    # different lowering (pallas interpret inserts while-loops), same entry
    ta, tb = open(a).read(), open(b).read()
    assert ta != tb
    assert "HloModule" in tb


def test_kernel_hlos_export(tmp_path):
    export_kernel_hlos(str(tmp_path), MODEL)
    for f in ("fake_quant.hlo.txt", "svd_score.hlo.txt"):
        text = open(os.path.join(str(tmp_path), f)).read()
        assert "HloModule" in text, f


def test_parity_vectors_selfconsistent(tmp_path):
    """The exported parity file must satisfy its own documented relations
    (the rust side re-checks the same relations against its own impls)."""
    path = str(tmp_path / "vectors.qtz")
    export_parity_vectors(path)
    t, meta = tensorfile.read(path)
    w = t["w"]
    assert meta["bits"] == 4 and meta["k"] == 64
    # deq lies on the scale grid
    codes = t["deq"] / t["scale"][0]
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    # colnorm matches x
    np.testing.assert_allclose(
        t["colnorm"], np.linalg.norm(t["x"], axis=0), rtol=1e-5
    )
    # xtx matches x
    np.testing.assert_allclose(t["xtx"], t["x"].T @ t["x"], rtol=1e-4, atol=1e-2)
    # topk mask has k ones and preserved keeps w there
    assert int(t["topk_mask"].sum()) == 64
    m = t["topk_mask"].astype(bool)
    np.testing.assert_array_equal(t["preserved"][m], w[m])
    # awq/svd/spqr scores nonnegative, right shape
    for k in ("awq_score", "svd_score", "spqr_score"):
        assert t[k].shape == w.shape
        assert (t[k] >= 0).all()
