"""Build-time fine-tuning of the task backbones (hand-rolled Adam — no optax
in this offline environment).

This stands in for the paper's TextAttack fine-tuned DistilBERT checkpoints
(DESIGN.md §2): each task gets its own trained model, saved as a .qtz
checkpoint that both the rust engine and the AOT-exported HLO consume.

Training runs once inside `make artifacts` and never on the request path.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import MODEL, ModelConfig, TaskConfig
from .data import Split
from .model import Params, forward, init_params, loss_fn

WARMUP_FRAC = 0.1


def _adam_step(p, g, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
    v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    p = jax.tree.map(
        lambda w, a, b: w - lr * (a / (jnp.sqrt(b) + eps) + wd * w), p, mh, vh
    )
    return p, m, v


def train_task(
    task: TaskConfig,
    splits: Dict[str, Split],
    cfg: ModelConfig = MODEL,
    batch_size: int = 32,
    log_every: int = 100,
    verbose: bool = True,
) -> Tuple[Params, Dict[str, float]]:
    """Train one backbone; returns (params, {train_acc, dev_acc, ...})."""
    params = init_params(cfg, seed=task.seed + 7)
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    grad_fn = jax.jit(
        jax.value_and_grad(
            lambda p, i, a, y: loss_fn(p, i, a, y, cfg), has_aux=True
        )
    )

    tr = splits["train"]
    n = tr.input_ids.shape[0]
    rng = np.random.default_rng(task.seed + 13)
    steps = task.train_steps
    warm = max(1, int(steps * WARMUP_FRAC))

    t0 = time.time()
    order = rng.permutation(n)
    cursor = 0
    for step in range(1, steps + 1):
        if cursor + batch_size > n:
            order = rng.permutation(n)
            cursor = 0
        idx = order[cursor : cursor + batch_size]
        cursor += batch_size
        bi = jnp.asarray(tr.input_ids[idx])
        ba = jnp.asarray(tr.attention_mask[idx])
        by = jnp.asarray(tr.labels[idx])
        # linear warmup then cosine decay
        if step <= warm:
            lr = task.lr * step / warm
        else:
            prog = (step - warm) / max(1, steps - warm)
            lr = task.lr * 0.5 * (1 + np.cos(np.pi * prog))
        (loss, acc), grads = grad_fn(params, bi, ba, by)
        params, m, v = _adam_step(params, grads, m, v, step, lr)
        if verbose and (step % log_every == 0 or step == 1):
            print(
                f"[{task.name}] step {step:4d}/{steps} "
                f"loss {float(loss):.4f} acc {float(acc):.3f} "
                f"lr {lr:.2e} ({time.time() - t0:.0f}s)",
                flush=True,
            )

    stats = {
        "train_steps": float(steps),
        "final_train_loss": float(loss),
        "dev_acc": evaluate(params, splits["dev"], cfg),
        "train_acc": evaluate(params, splits["train"], cfg, limit=1024),
        "wall_s": time.time() - t0,
    }
    if verbose:
        print(
            f"[{task.name}] done: dev_acc {stats['dev_acc']:.4f} "
            f"train_acc {stats['train_acc']:.4f} ({stats['wall_s']:.0f}s)",
            flush=True,
        )
    return params, stats


def evaluate(
    params: Params, split: Split, cfg: ModelConfig = MODEL, batch_size: int = 64,
    limit: int | None = None,
) -> float:
    """Dev accuracy of the FP32 model (python-side reference number)."""
    fwd = jax.jit(lambda p, i, a: jnp.argmax(forward(p, i, a, cfg), -1))
    ids, mask, labels = split.input_ids, split.attention_mask, split.labels
    if limit is not None:
        ids, mask, labels = ids[:limit], mask[:limit], labels[:limit]
    n = ids.shape[0]
    correct = 0
    for lo in range(0, n, batch_size):
        hi = min(lo + batch_size, n)
        # pad final batch to the jit shape
        bi = np.zeros((batch_size, cfg.max_len), np.int32)
        ba = np.zeros((batch_size, cfg.max_len), np.int32)
        bi[: hi - lo] = ids[lo:hi]
        ba[: hi - lo] = mask[lo:hi]
        pred = np.asarray(fwd(params, jnp.asarray(bi), jnp.asarray(ba)))
        correct += int((pred[: hi - lo] == labels[lo:hi]).sum())
    return correct / n
