"""AOT build entrypoint: data → train → export. Runs ONCE under
`make artifacts`; nothing in python/ is imported at runtime.

Outputs (under --out, default ../artifacts):

    data/<task>_{train,dev,calib}.qtz     datasets (tensorfile)
    ckpt/<task>.qtz                       trained FP32 parameters
    hlo/model_<task>.hlo.txt              fwd logits, plain-jnp path
    hlo/model_<task>_pallas.hlo.txt       fwd logits, Pallas-kernel path
    hlo/fake_quant.hlo.txt                standalone L1 kernel artifact
    hlo/svd_score.hlo.txt                 standalone L1 kernel artifact
    parity/vectors.qtz                    oracle vectors for the rust tests
    manifest.json                         shapes, arg order, config, hashes

Interchange is HLO **text**: jax ≥ 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the `xla` rust crate
binds) rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

The model HLOs take (input_ids i32[B,S], attention_mask i32[B,S], <params in
model.param_names() order>) and return a 1-tuple (logits f32[B,classes]) —
weights are *arguments*, so the rust side feeds arbitrarily quantized
parameters through one compiled executable.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as datamod
from . import tensorfile
from .config import (
    BUDGETS, CALIB_SAMPLES, CLIP_SIGMA, MODEL, QUANT_BITS, SPQR_DAMP,
    SVD_RANK, TASKS,
)
from .kernels import ref
from .kernels.fake_quant import fake_quant
from .kernels.svd_score import svd_score
from .model import forward, param_names
from .train import train_task


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sha(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        h.update(f.read())
    return h.hexdigest()[:16]


def export_model_hlo(params, cfg, out_path: str, use_pallas: bool, batch: int):
    names = param_names(cfg)

    def fn(ids, mask, *flat):
        p = dict(zip(names, flat))
        return (forward(p, ids, mask, cfg, use_pallas=use_pallas),)

    specs = [
        jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32),
        jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32),
    ] + [jax.ShapeDtypeStruct(params[n].shape, params[n].dtype) for n in names]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return text


def export_kernel_hlos(out_dir: str, cfg):
    """Standalone L1 kernel artifacts (used by rust parity tests)."""
    h, f = cfg.hidden, cfg.ffn
    # fake_quant over one ffn-shaped matrix
    fq = jax.jit(
        lambda w, c, s: (fake_quant(w, c, s, bits=QUANT_BITS),)
    ).lower(
        jax.ShapeDtypeStruct((f, h), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    with open(os.path.join(out_dir, "fake_quant.hlo.txt"), "w") as fh:
        fh.write(to_hlo_text(fq))
    # svd_score from rank-r factors
    sv = jax.jit(lambda u, s, v: (svd_score(u, s, v),)).lower(
        jax.ShapeDtypeStruct((f, SVD_RANK), jnp.float32),
        jax.ShapeDtypeStruct((SVD_RANK,), jnp.float32),
        jax.ShapeDtypeStruct((h, SVD_RANK), jnp.float32),
    )
    with open(os.path.join(out_dir, "svd_score.hlo.txt"), "w") as fh:
        fh.write(to_hlo_text(sv))


def export_parity_vectors(out_path: str):
    """Small oracle tensors the rust test-suite replays bit-for-bit
    (rust/tests/parity.rs): quantization, scoring, and top-k semantics."""
    rng = np.random.default_rng(0xDEC0DE)
    w = rng.normal(0, 0.05, size=(96, 160)).astype(np.float32)
    w[3, 7] = 0.9  # planted outliers exercise the clip path
    w[60, 100] = -0.8
    clip, scale = ref.quant_params(jnp.asarray(w), QUANT_BITS, CLIP_SIGMA)
    deq = ref.fake_quant_ref(jnp.asarray(w), clip, scale, QUANT_BITS)
    svd_sc = ref.svd_score_ref(jnp.asarray(w), SVD_RANK)

    x = rng.normal(0, 1.0, size=(64, 160)).astype(np.float32)
    colnorm = np.linalg.norm(x, axis=0).astype(np.float32)
    awq_sc = ref.awq_score_ref(jnp.asarray(w), jnp.asarray(colnorm))
    xtx = (x.T @ x).astype(np.float32)
    spqr_sc = ref.spqr_score_ref(
        jnp.asarray(w), jnp.asarray(xtx), x.shape[0], SPQR_DAMP
    )
    k = 64
    mask = ref.topk_mask(svd_sc, k)
    preserved = ref.preserve_ref(jnp.asarray(w), mask, clip, scale, QUANT_BITS)

    tensorfile.write(
        out_path,
        {
            "w": w,
            "x": x,
            "colnorm": colnorm,
            "xtx": xtx,
            "clip": np.asarray(clip, np.float32).reshape(1),
            "scale": np.asarray(scale, np.float32).reshape(1),
            "deq": np.asarray(deq),
            "svd_score": np.asarray(svd_sc),
            "awq_score": np.asarray(awq_sc),
            "spqr_score": np.asarray(spqr_sc),
            "topk_mask": np.asarray(mask).astype(np.uint8),
            "preserved": np.asarray(preserved),
        },
        meta={
            "bits": QUANT_BITS,
            "clip_sigma": CLIP_SIGMA,
            "svd_rank": SVD_RANK,
            "spqr_damp": SPQR_DAMP,
            "n_calib_rows": x.shape[0],
            "k": k,
        },
    )


def build(out_dir: str, tasks, skip_train: bool = False, quick: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    for sub in ("data", "ckpt", "hlo", "parity"):
        os.makedirs(os.path.join(out_dir, sub), exist_ok=True)

    manifest = {
        "model": MODEL.to_dict(),
        "param_names": param_names(MODEL),
        "budgets": BUDGETS,
        "svd_rank": SVD_RANK,
        "quant_bits": QUANT_BITS,
        "clip_sigma": CLIP_SIGMA,
        "spqr_damp": SPQR_DAMP,
        "calib_samples": CALIB_SAMPLES,
        "tasks": {},
        "files": {},
    }

    for name in tasks:
        task = TASKS[name]
        print(f"=== {name}: generating data ===", flush=True)
        splits = datamod.generate_task(name)
        for split, s in splits.items():
            path = os.path.join(out_dir, "data", f"{name}_{split}.qtz")
            tensorfile.write(
                path,
                {
                    "input_ids": s.input_ids,
                    "attention_mask": s.attention_mask,
                    "labels": s.labels,
                },
                meta={"task": name, "split": split, "n": int(s.labels.shape[0])},
            )

        ckpt_path = os.path.join(out_dir, "ckpt", f"{name}.qtz")
        if skip_train and os.path.exists(ckpt_path):
            print(f"=== {name}: reusing checkpoint ===", flush=True)
            arrays, meta = tensorfile.read(ckpt_path)
            params = {k: jnp.asarray(v) for k, v in arrays.items()}
            stats = meta.get("stats", {})
        else:
            print(f"=== {name}: training ===", flush=True)
            train_cfg = task
            if quick:
                import dataclasses

                train_cfg = dataclasses.replace(task, train_steps=30)
            params, stats = train_task(train_cfg, splits)
            tensorfile.write(
                ckpt_path,
                {k: np.asarray(v) for k, v in params.items()},
                meta={"task": name, "stats": stats, "model": MODEL.to_dict()},
            )

        print(f"=== {name}: exporting HLO ===", flush=True)
        t0 = time.time()
        hlo_path = os.path.join(out_dir, "hlo", f"model_{name}.hlo.txt")
        export_model_hlo(params, MODEL, hlo_path, use_pallas=False,
                         batch=MODEL.export_batch)
        # pallas variant at small batch: parity proof, not the sweep engine
        hlo_pallas = os.path.join(out_dir, "hlo", f"model_{name}_pallas.hlo.txt")
        export_model_hlo(params, MODEL, hlo_pallas, use_pallas=True, batch=8)
        print(f"    ({time.time()-t0:.0f}s)", flush=True)
        manifest["tasks"][name] = {
            "stats": stats,
            "paper_fp32": task.paper_fp32,
            "paper_q4_floor": task.paper_q4_floor,
            "n_train": task.n_train,
            "n_dev": task.n_dev,
            "n_calib": task.n_calib,
        }

    print("=== kernel artifacts + parity vectors ===", flush=True)
    export_kernel_hlos(os.path.join(out_dir, "hlo"), MODEL)
    export_parity_vectors(os.path.join(out_dir, "parity", "vectors.qtz"))

    for root, _, files in os.walk(out_dir):
        for fn in files:
            p = os.path.join(root, fn)
            rel = os.path.relpath(p, out_dir)
            if rel != "manifest.json":
                manifest["files"][rel] = _sha(p)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print("artifacts complete:", out_dir, flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--tasks", default=",".join(TASKS))
    ap.add_argument("--skip-train", action="store_true",
                    help="reuse existing checkpoints if present")
    ap.add_argument("--quick", action="store_true",
                    help="30-step training (CI smoke only)")
    args = ap.parse_args()
    build(os.path.abspath(args.out), args.tasks.split(","),
          skip_train=args.skip_train, quick=args.quick)


if __name__ == "__main__":
    main()
