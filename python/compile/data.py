"""Synthetic GLUE-analogue tasks (mrpc-syn / rte-syn / qnli-syn).

The paper evaluates on MRPC, RTE and QNLI with fine-tuned DistilBERT
checkpoints from TextAttack. Neither the checkpoints nor GLUE are available
in this offline environment (repro band 0/5), so we build analogues that
preserve the *property under study*: sentence-pair classification tasks that
a small transformer learns to a 0.65–0.9 ceiling, with enough headroom that
4-bit weight noise visibly moves dev accuracy (see DESIGN.md §2).

All three tasks share one integer vocabulary (no text tokenizer — sequences
are generated directly in token space):

    0 PAD   1 CLS   2 SEP   3 UNK
    [SYN_BASE, SYN_BASE + N_SYNSETS*SYNSET_SIZE)   synonym-set surface forms
    [ENT_BASE, ENT_BASE + N_ENTITIES)              entities
    [REL_BASE, REL_BASE + N_RELATIONS)             relations
    [VAL_BASE, VAL_BASE + N_VALUES)                values
    [QTY_BASE, QTY_BASE + N_RELATIONS)             question-type tokens
    [FIL_BASE, vocab)                              filler

All three tasks instantiate one pair-classification core a from-scratch
model of this size demonstrably learns (`_majority_pair`: latent-polarity
majority through synonym sets), with a margin knob that sets the ceiling.
We probed several structurally-faithful alternatives first — fact-triple
entailment, token-membership (subset) entailment, and cross-[SEP] synset
paraphrase matching — and a 4-layer model trained from scratch for ≤1k
steps stays at (or barely above) chance on all of them: the cross-sentence
matching they need relies on induction heads that do not form in this
training budget, whereas the paper's DistilBERT brings them from
pretraining. The majority core's decision rule (attention-average the
latent polarity of every content token) is representable by a single
attention layer, so it trains reliably, while the surface→synset→polarity
map still has to be *learned* (384 surface tokens, polarity never visible
in the token id ordering a linear model could exploit across synset
boundaries). See DESIGN.md §2.

* mrpc-syn — margins {1,2,4} over 12–20 tokens. Ceiling targets ≈0.86.
* rte-syn — margin {1} over 18–30 tokens (exact counting through soft
  attention → low ceiling) with the RTE-sized train set (2490) → mild
  overfit, the §VI-B "regularization" substrate. Ceiling targets ≈0.66.
* qnli-syn — margins {1,3,3} over 10–16 tokens, larger train set, a
  question-type token prefixing side A. Ceiling targets ≈0.88.

Determinism: every split is a pure function of (task seed, split). The rust
side never regenerates data — it reads the .qtz files from artifacts/data.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from .config import MODEL, TASKS, TaskConfig

# ---------------------------------------------------------------- vocabulary

PAD, CLS, SEP, UNK = 0, 1, 2, 3

# Symbol-space sizes are deliberately small: the backbone is trained from
# scratch (no pretraining, unlike the paper's DistilBERT), so every surface
# token must be seen often enough during fine-tuning for the matching
# operations (synonym classes, fact lookup) to generalize off the train set.
N_SYNSETS = 96
SYNSET_SIZE = 4
N_ENTITIES = 48
N_RELATIONS = 12
N_VALUES = 48

SYN_BASE = 8
ENT_BASE = SYN_BASE + N_SYNSETS * SYNSET_SIZE  # 392
REL_BASE = ENT_BASE + N_ENTITIES  # 440
VAL_BASE = REL_BASE + N_RELATIONS  # 452
QTY_BASE = VAL_BASE + N_VALUES  # 500
FIL_BASE = QTY_BASE + N_RELATIONS  # 512

assert FIL_BASE < MODEL.vocab_size


def synset_surface(rng: np.random.Generator, synset: np.ndarray) -> np.ndarray:
    """Map synset ids -> random surface tokens from each set."""
    member = rng.integers(0, SYNSET_SIZE, size=synset.shape)
    return SYN_BASE + synset * SYNSET_SIZE + member


def _pad_pair(a: np.ndarray, b: np.ndarray, max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """[CLS] a [SEP] b [SEP] -> fixed-length ids + mask."""
    seq = np.concatenate(([CLS], a, [SEP], b, [SEP]))
    seq = seq[:max_len]
    ids = np.full(max_len, PAD, dtype=np.int32)
    ids[: len(seq)] = seq
    mask = np.zeros(max_len, dtype=np.int32)
    mask[: len(seq)] = 1
    return ids, mask


# ---------------------------------------------------------------- mrpc-syn


# Half the synsets carry positive latent polarity, half negative. The
# surface never reveals polarity directly — the model must learn the
# 384-surface-token → 96-synset → polarity map from task data alone.
POS_SYNSETS = N_SYNSETS // 2


def _majority_pair(
    rng: np.random.Generator,
    n_lo: int,
    n_hi: int,
    margins: Tuple[int, ...],
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Shared pair core — latent-polarity majority (DESIGN.md §2).

    Sample n content synsets such that (#positive − #negative) = ±margin;
    the label is the sign. Surfaces are drawn per synset (synonym sets),
    the sequence is shuffled and split into an (A, B) pair at a random
    point, so examples keep the GLUE sentence-pair surface form.

    The decision rule is an attention-average over latent token polarity —
    a mechanism a 1-layer transformer can represent — so from-scratch
    training learns it quickly; the ``margins`` knob sets the ceiling
    (margin 1 needs exact counting through soft attention → low ceiling;
    margin ≥3 is nearly linearly separable → high ceiling).
    """
    n = int(rng.integers(n_lo, n_hi))
    margin = int(margins[int(rng.integers(0, len(margins)))])
    if (n + margin) % 2 == 1:
        n += 1
    label = int(rng.integers(0, 2))
    signed = margin if label == 1 else -margin
    n_pos = (n + signed) // 2
    pos = rng.integers(0, POS_SYNSETS, size=n_pos)
    neg = rng.integers(POS_SYNSETS, N_SYNSETS, size=n - n_pos)
    synsets = np.concatenate([pos, neg])
    rng.shuffle(synsets)
    seq = synset_surface(rng, synsets)
    cut = int(rng.integers(max(1, n // 3), max(2, 2 * n // 3)))
    return seq[:cut], seq[cut:], label


def _mrpc_example(rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray, int]:
    """Paraphrase-style pair, medium difficulty (paper band ≈ 0.86):
    margins {1,2,4} over 12–20 content tokens."""
    return _majority_pair(rng, 12, 21, margins=(1, 2, 4))


# ---------------------------------------------------------------- rte-syn


def _rte_example(rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray, int]:
    """Entailment analogue: hard mode — margin 1 over long sequences means
    the model must count latent polarity exactly through soft attention;
    with the RTE-sized train set (2490) the FP32 ceiling lands in the
    paper's ≈0.66 band and the model mildly overfits (the §VI-B
    "regularization" substrate)."""
    return _majority_pair(rng, 18, 31, margins=(1,))


# ---------------------------------------------------------------- qnli-syn


def _qnli_example(rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray, int]:
    """Answerability analogue: easy mode — margins {1,3,3} over short
    sequences, larger train set → ceiling near the paper's ≈0.88. A
    question-type token prefixes side A to keep the QNLI question/sentence
    surface form."""
    a, b, label = _majority_pair(rng, 10, 17, margins=(1, 3, 3))
    qtype = QTY_BASE + int(rng.integers(0, N_RELATIONS))
    return np.concatenate([[qtype], a]), b, label


_GENS = {"mrpc": _mrpc_example, "rte": _rte_example, "qnli": _qnli_example}

_SPLIT_SALT = {"train": 0, "dev": 1, "calib": 2}


@dataclass
class Split:
    input_ids: np.ndarray  # [N, S] i32
    attention_mask: np.ndarray  # [N, S] i32
    labels: np.ndarray  # [N] i32


def generate_split(task: TaskConfig, split: str) -> Split:
    n = {"train": task.n_train, "dev": task.n_dev, "calib": task.n_calib}[split]
    rng = np.random.default_rng([task.seed, _SPLIT_SALT[split], 0xC0FFEE])
    gen = _GENS[task.name]
    ids = np.zeros((n, MODEL.max_len), dtype=np.int32)
    mask = np.zeros((n, MODEL.max_len), dtype=np.int32)
    labels = np.zeros(n, dtype=np.int32)
    for i in range(n):
        a, b, y = gen(rng)
        ids[i], mask[i] = _pad_pair(a, b, MODEL.max_len)
        labels[i] = y
    # symmetric label noise (train only): the dev ceiling comes from task
    # hardness; the train noise keeps the model from memorizing cleanly and
    # pushes the FP32 dev accuracy into the paper's band.
    if split == "train" and task.label_noise > 0:
        flip = rng.random(n) < task.label_noise
        labels[flip] = 1 - labels[flip]
    return Split(ids, mask, labels)


def generate_task(name: str) -> Dict[str, Split]:
    task = TASKS[name]
    return {s: generate_split(task, s) for s in ("train", "dev", "calib")}
