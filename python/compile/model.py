"""L2: DistilBERT-style encoder classifier in JAX.

Two execution paths over identical parameters:

* ``forward(..., use_pallas=False)`` — plain jnp. Used for training and for
  the fast exported artifact (model_<task>.hlo.txt) that the rust sweep
  executes thousands of times.
* ``forward(..., use_pallas=True)`` — attention runs through the L1 Pallas
  kernel (kernels/attention.py) and every quantizable linear runs through
  kernels/salient_matmul.py with a trivial (all-quantized-bits-off) salient
  mask when no quantization is requested. Exported as
  model_<task>_pallas.hlo.txt; the rust parity test checks both executables
  agree on the same batch — the L1↔L2↔L3 composition proof.

Architecture (post-LN, matching distilbert-base-uncased):
    emb = LN(tok_emb[ids] + pos_emb[:s])
    per layer:  h = LN(h + MHSA(h));  h = LN(h + FFN(h)),  FFN = GELU
    head: CLS hidden → pre_classifier (h→h, ReLU) → classifier (h→classes)

Parameters live in a flat {name: array} dict — the same names appear in the
checkpoint .qtz files, in artifacts/manifest.json (as the HLO argument
order), and in the rust engine. See param_names().

Quantizable matrices (the paper's "per linear layer" budget applies to
each): layer{i}.{wq,wk,wv,wo,wf1,wf2} + pre_classifier.w + classifier.w.
Embeddings, biases and LayerNorms stay FP32, as in the paper's setup.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Params = Dict[str, jnp.ndarray]


# ------------------------------------------------------------- param layout


def param_names(cfg: ModelConfig) -> List[str]:
    """Canonical parameter order — also the HLO argument order after
    (input_ids, attention_mask)."""
    names = ["tok_emb", "pos_emb", "emb_ln_g", "emb_ln_b"]
    for i in range(cfg.layers):
        p = f"layer{i}."
        names += [
            p + "wq", p + "bq", p + "wk", p + "bk", p + "wv", p + "bv",
            p + "wo", p + "bo", p + "ln1_g", p + "ln1_b",
            p + "wf1", p + "bf1", p + "wf2", p + "bf2",
            p + "ln2_g", p + "ln2_b",
        ]
    names += ["pre_classifier.w", "pre_classifier.b", "classifier.w", "classifier.b"]
    return names


def quantizable_names(cfg: ModelConfig) -> List[str]:
    """The linear weight matrices subject to the paper's per-layer budget."""
    names = []
    for i in range(cfg.layers):
        p = f"layer{i}."
        names += [p + "wq", p + "wk", p + "wv", p + "wo", p + "wf1", p + "wf2"]
    names += ["pre_classifier.w", "classifier.w"]
    return names


def init_params(cfg: ModelConfig, seed: int) -> Params:
    """Truncated-normal-ish init (scaled normal), biases zero, LN unit."""
    rng = np.random.default_rng(seed)

    def dense(dout, din):
        return jnp.asarray(
            rng.normal(0.0, 0.02, size=(dout, din)).astype(np.float32)
        )

    h, f = cfg.hidden, cfg.ffn
    p: Params = {
        "tok_emb": jnp.asarray(
            rng.normal(0.0, 0.02, size=(cfg.vocab_size, h)).astype(np.float32)
        ),
        "pos_emb": jnp.asarray(
            rng.normal(0.0, 0.02, size=(cfg.max_len, h)).astype(np.float32)
        ),
        "emb_ln_g": jnp.ones(h, jnp.float32),
        "emb_ln_b": jnp.zeros(h, jnp.float32),
    }
    for i in range(cfg.layers):
        pre = f"layer{i}."
        p[pre + "wq"] = dense(h, h)
        p[pre + "bq"] = jnp.zeros(h, jnp.float32)
        p[pre + "wk"] = dense(h, h)
        p[pre + "bk"] = jnp.zeros(h, jnp.float32)
        p[pre + "wv"] = dense(h, h)
        p[pre + "bv"] = jnp.zeros(h, jnp.float32)
        p[pre + "wo"] = dense(h, h)
        p[pre + "bo"] = jnp.zeros(h, jnp.float32)
        p[pre + "ln1_g"] = jnp.ones(h, jnp.float32)
        p[pre + "ln1_b"] = jnp.zeros(h, jnp.float32)
        p[pre + "wf1"] = dense(f, h)
        p[pre + "bf1"] = jnp.zeros(f, jnp.float32)
        p[pre + "wf2"] = dense(h, f)
        p[pre + "bf2"] = jnp.zeros(h, jnp.float32)
        p[pre + "ln2_g"] = jnp.ones(h, jnp.float32)
        p[pre + "ln2_b"] = jnp.zeros(h, jnp.float32)
    p["pre_classifier.w"] = dense(h, h)
    p["pre_classifier.b"] = jnp.zeros(h, jnp.float32)
    p["classifier.w"] = dense(cfg.n_classes, h)
    p["classifier.b"] = jnp.zeros(cfg.n_classes, jnp.float32)
    return p


# ------------------------------------------------------------------ forward


def _ln(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-12):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _linear(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, use_pallas: bool):
    """y = x @ wᵀ + b. The pallas path routes through salient_matmul with an
    identity configuration (mask=1 everywhere, s_dense=w): the kernel then
    computes exactly x@wᵀ while exercising the deploy-time code path."""
    if not use_pallas:
        return x @ w.T + b
    from .kernels.salient_matmul import salient_matmul

    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    dout = w.shape[0]
    q = jnp.zeros(w.shape, jnp.int8)
    scale = jnp.ones((dout,), jnp.float32)
    mask = jnp.ones(w.shape, jnp.float32)
    y = salient_matmul(x2, q, scale, w, mask)
    return y.reshape(*shp[:-1], dout) + b


def _attention_block(
    h: jnp.ndarray, mask: jnp.ndarray, p: Params, pre: str, cfg: ModelConfig,
    use_pallas: bool,
) -> jnp.ndarray:
    b, s, d = h.shape
    nh, dh = cfg.heads, cfg.head_dim
    q = _linear(h, p[pre + "wq"], p[pre + "bq"], use_pallas)
    k = _linear(h, p[pre + "wk"], p[pre + "bk"], use_pallas)
    v = _linear(h, p[pre + "wv"], p[pre + "bv"], use_pallas)

    def split(t):
        return t.reshape(b, s, nh, dh).transpose(0, 2, 1, 3).reshape(b * nh, s, dh)

    qh, kh, vh = split(q), split(k), split(v)
    mh = jnp.repeat(mask.astype(jnp.float32), nh, axis=0)  # [b*nh, s]
    if use_pallas:
        from .kernels.attention import attention as attn_kernel

        ctx = attn_kernel(qh, kh, vh, mh)
    else:
        logits = jnp.einsum("bqd,bkd->bqk", qh, kh) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32)
        )
        logits = jnp.where(mh[:, None, :] > 0, logits, -1e9)
        ctx = jax.nn.softmax(logits, axis=-1) @ vh
    ctx = ctx.reshape(b, nh, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return _linear(ctx, p[pre + "wo"], p[pre + "bo"], use_pallas)


def forward(
    p: Params,
    input_ids: jnp.ndarray,
    attention_mask: jnp.ndarray,
    cfg: ModelConfig,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Logits [B, n_classes] from token ids [B, S] and mask [B, S]."""
    b, s = input_ids.shape
    h = p["tok_emb"][input_ids] + p["pos_emb"][None, :s, :]
    h = _ln(h, p["emb_ln_g"], p["emb_ln_b"])
    for i in range(cfg.layers):
        pre = f"layer{i}."
        attn = _attention_block(h, attention_mask, p, pre, cfg, use_pallas)
        h = _ln(h + attn, p[pre + "ln1_g"], p[pre + "ln1_b"])
        f = _linear(h, p[pre + "wf1"], p[pre + "bf1"], use_pallas)
        f = jax.nn.gelu(f, approximate=False)
        f = _linear(f, p[pre + "wf2"], p[pre + "bf2"], use_pallas)
        h = _ln(h + f, p[pre + "ln2_g"], p[pre + "ln2_b"])
    cls = h[:, 0, :]
    z = jax.nn.relu(
        _linear(cls, p["pre_classifier.w"], p["pre_classifier.b"], use_pallas)
    )
    return _linear(z, p["classifier.w"], p["classifier.b"], use_pallas)


# --------------------------------------------------------------------- loss


def loss_fn(
    p: Params,
    input_ids: jnp.ndarray,
    attention_mask: jnp.ndarray,
    labels: jnp.ndarray,
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    logits = forward(p, input_ids, attention_mask, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == labels).mean()
    return nll, acc
