"""Model + task configuration shared by data generation, training and AOT
export. The rust side reads the same values from artifacts/manifest.json —
change them here, re-run `make artifacts`, and everything stays consistent.
"""

from __future__ import annotations

from dataclasses import dataclass, field, asdict
from typing import Dict, List


@dataclass(frozen=True)
class ModelConfig:
    """DistilBERT-style encoder (post-LN, GELU FFN, learned positions)."""

    vocab_size: int = 2048
    max_len: int = 48
    hidden: int = 256
    layers: int = 4
    heads: int = 4
    ffn: int = 1024
    n_classes: int = 2
    # batch size baked into the exported HLO (shape-static executable);
    # the rust eval harness pads the last batch.
    export_batch: int = 64

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def to_dict(self) -> Dict:
        return asdict(self)


@dataclass(frozen=True)
class TaskConfig:
    """One synthetic GLUE-analogue task."""

    name: str
    n_train: int
    n_dev: int
    n_calib: int
    label_noise: float
    train_steps: int
    lr: float
    seed: int
    # paper reference points (FP32 ceiling / Q4 floor) for EXPERIMENTS.md
    paper_fp32: float = 0.0
    paper_q4_floor: float = 0.0


# Train-set sizes mirror the real GLUE splits in spirit: RTE is deliberately
# small (the paper's "regularization effect" on RTE depends on mild
# overfitting), QNLI largest. Dev sizes match the real dev splits.
TASKS: Dict[str, TaskConfig] = {
    "mrpc": TaskConfig(
        name="mrpc", n_train=6000, n_dev=408, n_calib=128,
        label_noise=0.08, train_steps=500, lr=3e-4, seed=101,
        paper_fp32=0.8578, paper_q4_floor=0.8358,
    ),
    "rte": TaskConfig(
        name="rte", n_train=2490, n_dev=277, n_calib=128,
        label_noise=0.08, train_steps=600, lr=3e-4, seed=202,
        paper_fp32=0.6570, paper_q4_floor=0.6245,
    ),
    "qnli": TaskConfig(
        name="qnli", n_train=8000, n_dev=1000, n_calib=128,
        label_noise=0.05, train_steps=500, lr=3e-4, seed=303,
        paper_fp32=0.8849, paper_q4_floor=0.8775,
    ),
}

TASK_NAMES: List[str] = list(TASKS)

MODEL = ModelConfig()

# Paper §IV-B protection budgets (salient weights kept FP32, per linear layer)
BUDGETS: List[int] = [1, 16, 64, 256, 1024, 4096]

# Paper §III-A4: rank of the principal reconstruction (PiSSA convention)
SVD_RANK: int = 8

# Paper §III-B: symmetric linear quantization of the residual
QUANT_BITS: int = 4
CLIP_SIGMA: float = 2.5  # |w| clipped at 2.5·std(W) before scale computation

# Paper §III-A3: damping for the SpQR Hessian inverse
SPQR_DAMP: float = 0.01

# Paper §IV-B: calibration samples for AWQ / SpQR
CALIB_SAMPLES: int = 128
