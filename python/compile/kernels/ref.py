"""Pure-jnp oracles for every Pallas kernel (and for the rust quantizer).

These are the single source of truth for numerics: pytest checks each Pallas
kernel against its oracle here, and the rust test-suite checks the rust
implementations against values exported from these functions (see
aot.py --parity which emits artifacts/parity/vectors.qtz).

Conventions (paper §III):
    W ≈ S + Q      S: top-k salient entries kept FP32 (dense-with-zeros here)
                   Q: symmetric b-bit quantization of the residual
    scale = max|clip(W)| / (2^{b-1} - 1), per-tensor   (eq. 8–9)
    clip at CLIP_SIGMA · std(W)                         (§III-B)
    Score_SVD(w_ij) = |(U_r Σ_r V_rᵀ)_ij|, r = 8        (eq. 5–7)
    Score_AWQ(w_ij) = |w_ij| · ‖X_j‖₂                   (eq. 3)
    Score_SpQR(w_ij) = w_ij² / [H⁻¹]_jj, H = (2/N)XᵀX + λ·mean(diag)·I  (eq. 4)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_params(w: jnp.ndarray, bits: int = 4, clip_sigma: float = 2.5):
    """(clip threshold, scale) for symmetric linear quantization, eq. 8-9."""
    sigma = jnp.std(w)
    clip = clip_sigma * sigma
    wc = jnp.clip(w, -clip, clip)
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.max(jnp.abs(wc)) / qmax
    # degenerate all-zero matrix: scale 1 avoids 0/0 and round-trips zeros
    scale = jnp.where(scale > 0, scale, 1.0)
    return clip, scale


def fake_quant_ref(
    w: jnp.ndarray, clip: jnp.ndarray, scale: jnp.ndarray, bits: int = 4
) -> jnp.ndarray:
    """Simulated quantize→dequantize of a weight tensor (eq. 8)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    wc = jnp.clip(w, -clip, clip)
    q = jnp.clip(jnp.round(wc / scale), -qmax, qmax)
    return q * scale


def svd_score_ref(w: jnp.ndarray, rank: int = 8) -> jnp.ndarray:
    """|rank-r principal reconstruction| of w (eq. 5-7)."""
    u, s, vt = jnp.linalg.svd(w, full_matrices=False)
    r = min(rank, s.shape[0])
    w_pri = (u[:, :r] * s[:r]) @ vt[:r, :]
    return jnp.abs(w_pri)


def svd_score_from_factors_ref(
    u_r: jnp.ndarray, s_r: jnp.ndarray, v_r: jnp.ndarray
) -> jnp.ndarray:
    """Score map given precomputed factors: |U_r diag(s) V_rᵀ|.

    (u_r: [dout, r], s_r: [r], v_r: [din, r]) — the shape the Pallas kernel
    consumes; the SVD factorization itself is not a kernel-friendly op.
    """
    return jnp.abs((u_r * s_r) @ v_r.T)


def awq_score_ref(w: jnp.ndarray, x_colnorm: jnp.ndarray) -> jnp.ndarray:
    """AWQ saliency |w_ij|·‖X_j‖₂ (eq. 3). x_colnorm: [din]."""
    return jnp.abs(w) * x_colnorm[None, :]


def spqr_score_ref(
    w: jnp.ndarray, xtx: jnp.ndarray, n: int, damp: float = 0.01
) -> jnp.ndarray:
    """SpQR/OBS saliency w²/[H⁻¹]_jj (eq. 4) with damped empirical Hessian."""
    d = xtx.shape[0]
    h = (2.0 / n) * xtx
    h = h + damp * jnp.mean(jnp.diag(h)) * jnp.eye(d, dtype=w.dtype)
    hinv = jnp.linalg.inv(h)
    return w**2 / jnp.diag(hinv)[None, :]


def topk_mask(score: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k highest-scoring entries (ties broken by index)."""
    flat = score.reshape(-1)
    k = min(k, flat.shape[0])
    idx = jnp.argsort(-flat, stable=True)[:k]
    mask = jnp.zeros_like(flat, dtype=bool).at[idx].set(True)
    return mask.reshape(score.shape)


def preserve_ref(
    w: jnp.ndarray,
    mask: jnp.ndarray,
    clip: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int = 4,
) -> jnp.ndarray:
    """W ≈ S + Q: salient entries exact, the rest fake-quantized (eq. 1)."""
    return jnp.where(mask, w, fake_quant_ref(w, clip, scale, bits))


def salient_matmul_ref(
    x: jnp.ndarray,
    q: jnp.ndarray,
    scale: jnp.ndarray,
    s_dense: jnp.ndarray,
    mask: jnp.ndarray,
) -> jnp.ndarray:
    """y = x @ W_effᵀ with W_eff = (1-mask)·scale·q + mask·s_dense.

    x: [m, din], q: int8 codes [dout, din], scale: [dout] (per-row),
    s_dense: salient FP32 values (0 off-mask), mask: {0,1} f32.
    """
    w_eff = (1.0 - mask) * (scale[:, None] * q.astype(x.dtype)) + mask * s_dense
    return x @ w_eff.T


def attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked scaled-dot-product attention for one (batch, head) slice.

    q,k,v: [s, dh]; mask: [s] with 1=real token, 0=pad.
    """
    dh = q.shape[-1]
    logits = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    neg = jnp.asarray(-1e9, q.dtype)
    logits = jnp.where(mask[None, :] > 0, logits, neg)
    p = jax.nn.softmax(logits, axis=-1)
    return p @ v
