"""L1 Pallas kernel: fused masked SDPA for the encoder (one block per
(batch·head) grid cell).

Used by the `pallas` variant of the L2 model (model.py) so that the exported
model_*_pallas.hlo.txt artifact exercises a Pallas kernel *inside* the same
HLO the rust runtime executes — the L1↔L2↔L3 composition proof.

Sequence length here is small (max_len = 48), so one grid cell holds the
whole (s, dh) problem in VMEM and the softmax needs no online/flash
decomposition: VMEM/step = 3·s·dh·4 + s²·4 + s·dh·4 ≈ 58 KiB at s=48,
dh=64. On a real TPU with long sequences this kernel is where a flash-style
k-loop would go; the paper's workloads (GLUE, ≤128 tokens) never need it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, m_ref, o_ref, *, sm_scale: float):
    q = q_ref[0]  # [s, dh]
    k = k_ref[0]
    v = v_ref[0]
    mask = m_ref[0]  # [s]
    logits = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * sm_scale
    )
    logits = jnp.where(mask[None, :] > 0, logits, -1e9)
    p = jax.nn.softmax(logits, axis=-1)
    o_ref[0] = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@jax.jit
def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Masked multi-head attention.

    q,k,v: [bh, s, dh] (batch·heads flattened), mask: [bh, s] {0,1} f32
    → [bh, s, dh].
    """
    bh, s, dh = q.shape
    sm_scale = 1.0 / (dh**0.5)
    return pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, s), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        mask.astype(jnp.float32),
    )
