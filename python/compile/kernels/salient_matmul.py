"""L1 Pallas kernel: mixed-precision linear  y = x @ ((1-M)·s·Q + M·S)ᵀ.

The deploy-time hot path of the paper's scheme (eq. 1): the weight is stored
as 4-bit codes Q (int8 container here; 2-nibble packing is a storage detail
handled by the rust engine) plus a sparse FP32 salient component S. The
kernel dequantizes per-tile and applies the salient entries as a dense
mask-add *on the tile* before the MXU contraction.

Why mask-add instead of scatter (DESIGN.md §6): a sparse scatter into the
systolic pipeline stalls the MXU; merging S as `(1-M)·deq + M·S` keeps the
contraction dense and the epilogue elementwise, which is exactly the trade
AWQ/SpQR inference kernels make on GPU (dense compute + sparse side-channel
folded in). k ≤ 4096 per layer → M is extremely sparse, but the tile-level
mask-add costs the same regardless of sparsity and never branches.

Grid: (m-tiles, dout-tiles, din-tiles); the f32 accumulator tile lives in
VMEM across the din-contraction (out_spec index ignores the k axis, so the
same output block is revisited — standard Pallas accumulation pattern).
VMEM/step ≈ bm·bk·4 + 3·bn·bk·4 + bm·bn·4 + bn·4 bytes
(defaults 64·256·4 + 3·128·256·4 + 64·128·4 + 128·4 ≈ 480 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, scale_ref, s_ref, m_ref, o_ref, *, k_steps: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bm, bk] f32
    q = q_ref[...].astype(jnp.float32)  # [bn, bk] codes
    scale = scale_ref[...]  # [bn] per-row scales
    s = s_ref[...]  # [bn, bk] salient values (0 off-mask)
    m = m_ref[...]  # [bn, bk] {0,1}
    w_eff = (1.0 - m) * (scale[:, None] * q) + m * s
    o_ref[...] += jax.lax.dot_general(
        x, w_eff, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def salient_matmul(
    x: jnp.ndarray,
    q: jnp.ndarray,
    scale: jnp.ndarray,
    s_dense: jnp.ndarray,
    mask: jnp.ndarray,
    block_m: int = 64,
    block_n: int = 128,
    block_k: int = 256,
) -> jnp.ndarray:
    """Mixed-precision linear layer.

    x: [m, din] f32, q: [dout, din] int8 codes, scale: [dout] f32,
    s_dense: [dout, din] f32 (salient values, 0 elsewhere),
    mask: [dout, din] f32 {0,1} → y: [m, dout] f32.
    """
    m, din = x.shape
    dout, din2 = q.shape
    assert din == din2 and scale.shape == (dout,)
    assert s_dense.shape == q.shape and mask.shape == q.shape
    bm, bn, bk = min(block_m, m), min(block_n, dout), min(block_k, din)
    # The contraction axis must divide bk exactly: the accumulating
    # multi-k-step pattern is not safe under implicit block padding
    # (observed NaN/garbage on the ragged final block in interpret mode).
    # Zero-pad explicitly — zero columns contribute nothing to the dot.
    if din % bk != 0:
        pad = bk - din % bk
        x = jnp.pad(x, ((0, 0), (0, pad)))
        q = jnp.pad(q, ((0, 0), (0, pad)))
        s_dense = jnp.pad(s_dense, ((0, 0), (0, pad)))
        # padded mask = 1 with s=0 keeps w_eff exactly 0 there
        mask = jnp.pad(mask, ((0, 0), (0, pad)), constant_values=1.0)
        din = din + pad
    k_steps = pl.cdiv(din, bk)
    grid = (pl.cdiv(m, bm), pl.cdiv(dout, bn), k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, dout), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), q, scale.astype(jnp.float32), s_dense, mask)
