"""L1 Pallas kernels (build-time only; lowered into HLO by aot.py)."""
from . import ref  # noqa: F401
from .fake_quant import fake_quant  # noqa: F401
from .svd_score import svd_score  # noqa: F401
from .salient_matmul import salient_matmul  # noqa: F401
from .attention import attention  # noqa: F401
