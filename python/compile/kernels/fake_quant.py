"""L1 Pallas kernel: simulated symmetric quantize→dequantize with clipping.

Paper §III-B (eq. 8–9): the residual component Q is quantized to b bits with
a per-tensor scale derived from the clipped max. The clip threshold and the
scale are *global* reductions, so they are computed once on the host side
(`quant_params` in ref.py / aot callers) and fed to the kernel as scalars in
SMEM — the kernel itself is a purely elementwise HBM-bandwidth-bound pass
over W, tiled so each (block_m, block_n) tile lives in VMEM.

TPU mapping (DESIGN.md §6): one input tile + one output tile per grid step,
VMEM footprint = 2·bm·bn·4 bytes (default 2·128·256·4 = 256 KiB), no MXU use
— the roofline is HBM bandwidth and the kernel reads W exactly once.

interpret=True everywhere in this repo: the CPU PJRT plugin cannot execute
Mosaic custom-calls; numerics are identical (see DESIGN.md §2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(params_ref, w_ref, o_ref, *, qmax: float):
    clip = params_ref[0]
    scale = params_ref[1]
    w = w_ref[...]
    wc = jnp.clip(w, -clip, clip)
    q = jnp.clip(jnp.round(wc / scale), -qmax, qmax)
    o_ref[...] = q * scale


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n"))
def fake_quant(
    w: jnp.ndarray,
    clip: jnp.ndarray,
    scale: jnp.ndarray,
    bits: int = 4,
    block_m: int = 128,
    block_n: int = 256,
) -> jnp.ndarray:
    """Quantize-dequantize `w` (2-D, f32) to `bits` with clipping.

    clip/scale are scalars (see ref.quant_params). Shapes that do not divide
    the block are handled by Pallas' implicit padding: the padded lanes are
    written but never read back (out_shape == w.shape).
    """
    assert w.ndim == 2, "fake_quant expects a weight matrix"
    m, n = w.shape
    bm, bn = min(block_m, m), min(block_n, n)
    qmax = 2.0 ** (bits - 1) - 1.0
    params = jnp.stack([clip.astype(w.dtype), scale.astype(w.dtype)])
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))
    return pl.pallas_call(
        functools.partial(_kernel, qmax=qmax),
        grid=grid,
        in_specs=[
            # scalar params are replicated to every grid step
            pl.BlockSpec((2,), lambda i, j: (0,)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(params, w)
