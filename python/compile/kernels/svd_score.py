"""L1 Pallas kernel: SVD saliency score map |U_r Σ_r V_rᵀ| (paper eq. 5–7).

This is the paper's data-free scoring hot-spot. The factors (U_r, Σ_r, V_r)
come from a rank-r factorization done once per matrix (host side / rust
linalg::rsvd); the kernel materializes the score of every weight:

    score[i, j] = | Σ_t  U[i,t] · s[t] · V[j,t] |

Structure: an outer-product matmul with tiny inner dimension r (= 8). On TPU
(DESIGN.md §6) each grid step loads a (bm, r) strip of U·diag(s) and a
(bn, r) strip of V into VMEM and emits one (bm, bn) score tile — the kernel
is bandwidth-bound on the *output* (reads r·(bm+bn) floats, writes bm·bn),
so block sizes are chosen to keep the MXU busy on the (bm,r)x(r,bn) contract
while the next strips stream in. VMEM/step = (bm+bn)·r·4 + bm·bn·4 bytes
(defaults: (128+256)·8·4 + 128·256·4 ≈ 140 KiB).

Fusing the |·| into the matmul epilogue saves a full extra HBM round-trip
over the naive "reconstruct, then abs" two-pass formulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(us_ref, v_ref, o_ref):
    us = us_ref[...]  # [bm, r]  (U already scaled by s)
    v = v_ref[...]  # [bn, r]
    o_ref[...] = jnp.abs(
        jax.lax.dot_general(
            us, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def svd_score(
    u_r: jnp.ndarray,
    s_r: jnp.ndarray,
    v_r: jnp.ndarray,
    block_m: int = 128,
    block_n: int = 256,
) -> jnp.ndarray:
    """Score map from rank-r factors.

    u_r: [dout, r], s_r: [r], v_r: [din, r] → [dout, din] f32 scores.
    """
    dout, r = u_r.shape
    din, r2 = v_r.shape
    assert r == r2 == s_r.shape[0]
    bm, bn = min(block_m, dout), min(block_n, din)
    us = (u_r * s_r[None, :]).astype(jnp.float32)
    grid = (pl.cdiv(dout, bm), pl.cdiv(din, bn))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dout, din), jnp.float32),
        interpret=True,
    )(us, v_r.astype(jnp.float32))
