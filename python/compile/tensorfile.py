"""qtz tensor container — the interchange format between the python compile
path and the rust runtime.

Layout (all little-endian):

    bytes 0..4    magic  b"QTZ1"
    bytes 4..8    u32    header_len (bytes of JSON that follow)
    bytes 8..8+h  JSON   {"tensors": {name: {"dtype", "shape", "offset",
                          "nbytes"}}, "meta": {...}}
    then          raw tensor bytes; each tensor's offset is relative to the
                  start of the data section and 64-byte aligned.

dtypes: "f32", "i32", "i64", "u8", "i8". The rust reader lives in
rust/src/tensorfile/. Keep the two implementations in lock-step; the format
is deliberately trivial (safetensors-like) so both sides stay small.
"""

from __future__ import annotations

import json
import struct
from typing import Dict, Any, Tuple

import numpy as np

MAGIC = b"QTZ1"
ALIGN = 64

_DTYPES = {
    "f32": np.float32,
    "i32": np.int32,
    "i64": np.int64,
    "u8": np.uint8,
    "i8": np.int8,
}
_NP2STR = {np.dtype(v): k for k, v in _DTYPES.items()}


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def write(path: str, tensors: Dict[str, np.ndarray], meta: Dict[str, Any] | None = None) -> None:
    """Write a dict of numpy arrays (+ JSON-able metadata) to `path`."""
    entries: Dict[str, Any] = {}
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        if arr.dtype not in _NP2STR:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes()
        entries[name] = {
            "dtype": _NP2STR[arr.dtype],
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
        }
        blobs.append((offset, raw))
        offset = _align(offset + len(raw))
    header = json.dumps(
        {"tensors": entries, "meta": meta or {}}, separators=(",", ":"), sort_keys=True
    ).encode("utf-8")
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        written = 0
        for off, raw in blobs:
            if off > written:  # inter-tensor alignment padding
                f.write(b"\x00" * (off - written))
                written = off
            f.write(raw)
            written += len(raw)
        # pad the tail so the file size is also aligned (simplifies mmap)
        end = _align(written)
        if end > written:
            f.write(b"\x00" * (end - written))


def read(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read a qtz file back into {name: array}, meta."""
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:4] != MAGIC:
        raise ValueError(f"{path}: bad magic {blob[:4]!r}")
    (hlen,) = struct.unpack("<I", blob[4:8])
    header = json.loads(blob[8 : 8 + hlen].decode("utf-8"))
    data = blob[8 + hlen :]
    out: Dict[str, np.ndarray] = {}
    for name, ent in header["tensors"].items():
        dt = _DTYPES[ent["dtype"]]
        start, n = ent["offset"], ent["nbytes"]
        arr = np.frombuffer(data[start : start + n], dtype=dt).reshape(ent["shape"])
        out[name] = arr.copy()
    return out, header.get("meta", {})
