"""qtz tensor container — the interchange format between the python compile
path and the rust runtime.

Layout (all little-endian):

    bytes 0..4    magic  b"QTZ1" (checkpoints) or b"QTZ2" (quantized-model
                         artifacts, which carry an explicit format version)
    bytes 4..8    u32    header_len (bytes of JSON that follow)
    bytes 8..8+h  JSON   {"tensors": {name: {"dtype", "shape", "offset",
                          "nbytes", "crc32"?}}, "meta": {...},
                          "version"?: int}
                         — space-padded so the data section starts at a
                         64-byte-aligned absolute file offset
    then          raw tensor bytes; each tensor's offset is relative to the
                  start of the data section and 64-byte aligned.

dtypes: "f32", "i32", "i64", "u8", "i8", "u32". Per-tensor "crc32" is the
zlib/IEEE CRC-32 of the raw bytes; readers verify it when present (legacy
files without it still load). QTZ2 files carry "version"; readers refuse
versions newer than FORMAT_VERSION. The rust reader/writer lives in
rust/src/tensorfile/. Keep the two implementations in lock-step; the
format is deliberately trivial (safetensors-like) so both sides stay
small.
"""

from __future__ import annotations

import json
import struct
import zlib
from typing import Dict, Any, Tuple

import numpy as np

MAGIC_V1 = b"QTZ1"
MAGIC_V2 = b"QTZ2"
MAGIC = MAGIC_V1  # legacy alias
ALIGN = 64
FORMAT_VERSION = 1

_DTYPES = {
    "f32": np.float32,
    "i32": np.int32,
    "i64": np.int64,
    "u8": np.uint8,
    "i8": np.int8,
    "u32": np.uint32,
}
_NP2STR = {np.dtype(v): k for k, v in _DTYPES.items()}


def _align(n: int) -> int:
    return (n + ALIGN - 1) // ALIGN * ALIGN


def write(
    path: str,
    tensors: Dict[str, np.ndarray],
    meta: Dict[str, Any] | None = None,
    qtz2: bool = False,
) -> None:
    """Write a dict of numpy arrays (+ JSON-able metadata) to `path`.

    `qtz2=True` stamps the artifact magic and an explicit format version
    (the rust `TensorFile::save_qtz2` counterpart); the default writes a
    legacy checkpoint container. Both stamp per-tensor crc32 and pad the
    header so the data section is 64-byte aligned in the file.
    """
    entries: Dict[str, Any] = {}
    blobs = []
    offset = 0
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name])
        if arr.dtype not in _NP2STR:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes()
        entries[name] = {
            "dtype": _NP2STR[arr.dtype],
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
        }
        blobs.append((offset, raw))
        offset = _align(offset + len(raw))
    doc: Dict[str, Any] = {"tensors": entries, "meta": meta or {}}
    if qtz2:
        doc["version"] = FORMAT_VERSION
    header = json.dumps(doc, separators=(",", ":"), sort_keys=True).encode("utf-8")
    # space-pad so the data section starts 64-byte aligned in the file
    # (JSON parsers on both sides tolerate trailing whitespace)
    header += b" " * (_align(8 + len(header)) - 8 - len(header))
    with open(path, "wb") as f:
        f.write(MAGIC_V2 if qtz2 else MAGIC_V1)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        written = 0
        for off, raw in blobs:
            if off > written:  # inter-tensor alignment padding
                f.write(b"\x00" * (off - written))
                written = off
            f.write(raw)
            written += len(raw)
        # pad the tail so the file size is also aligned (simplifies mmap)
        end = _align(written)
        if end > written:
            f.write(b"\x00" * (end - written))


def read(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Read a qtz file (either magic) back into {name: array}, meta.

    Verifies per-tensor crc32 when present and refuses containers written
    by a newer format version — mirror of the rust `TensorFileView`.
    """
    with open(path, "rb") as f:
        blob = f.read()
    if len(blob) < 8:
        raise ValueError(f"{path}: truncated file ({len(blob)} bytes)")
    magic = blob[:4]
    if magic not in (MAGIC_V1, MAGIC_V2):
        raise ValueError(f"{path}: bad magic {magic!r}")
    (hlen,) = struct.unpack("<I", blob[4:8])
    if 8 + hlen > len(blob):
        raise ValueError(f"{path}: truncated header")
    header = json.loads(blob[8 : 8 + hlen].decode("utf-8"))
    version = header.get("version", 0)
    if magic == MAGIC_V2 and "version" not in header:
        raise ValueError(f"{path}: QTZ2 header missing version")
    if version > FORMAT_VERSION:
        raise ValueError(
            f"{path}: unsupported container version {version} "
            f"(this reader understands <= {FORMAT_VERSION}; written by a newer tool)"
        )
    data = blob[8 + hlen :]
    out: Dict[str, np.ndarray] = {}
    for name, ent in header["tensors"].items():
        dt = _DTYPES[ent["dtype"]]
        start, n = ent["offset"], ent["nbytes"]
        if start + n > len(data):
            raise ValueError(f"{path}: tensor {name} extends past end of file")
        raw = data[start : start + n]
        want = ent.get("crc32")
        if want is not None:
            got = zlib.crc32(raw) & 0xFFFFFFFF
            if got != want:
                raise ValueError(
                    f"{path}: tensor {name}: checksum mismatch "
                    f"(stored {want:#010x}, computed {got:#010x}) — file is corrupt"
                )
        arr = np.frombuffer(raw, dtype=dt).reshape(ent["shape"])
        out[name] = arr.copy()
    return out, header.get("meta", {})
