//! Fig. 2 deep-dive: *is SVD finding the same weights as the Hessian?*
//!
//! Reproduces the paper's overlap analysis per layer (not just the
//! aggregate): IoU of the SVD-selected index set vs AWQ and SpQR at each
//! budget, plus the exact-vs-randomized SVD agreement ablation
//! (DESIGN.md §5).
//!
//! ```sh
//! cargo run --release --offline --example overlap_analysis [task]
//! ```

use svdquant::calib::CalibStats;
use svdquant::coordinator::{score_layer, Artifacts, PreserveSpec};
use svdquant::model::Engine;
use svdquant::saliency::{iou, select_topk, Method, SvdScoreMode};

fn main() -> anyhow::Result<()> {
    let task = std::env::args().nth(1).unwrap_or_else(|| "mrpc".to_string());
    let art = Artifacts::open("artifacts")?;
    let ckpt = art.checkpoint(&task)?;
    let calib_data = art.dataset(&task, "calib")?;
    let engine = Engine::new(art.model_cfg, ckpt)?;
    let calib = CalibStats::collect(&engine, &calib_data, art.calib_samples(), 16)?;
    let ckpt = engine.params();

    let spec_of = |m: Method| PreserveSpec {
        method: m,
        spqr_damp: art.spqr_damp(),
        ..Default::default()
    };

    let budgets = [16usize, 256, 4096];
    println!("per-layer IoU of SVD selections vs baselines ({task})\n");
    println!("{:<22} {:>6}  {:>8} {:>8} {:>10}", "layer", "k", "vs AWQ", "vs SpQR", "rsvd/exact");
    let names = art.model_cfg.quantizable_names();
    for name in &names {
        let w = ckpt.get(name)?;
        let svd = score_layer(name, w, &spec_of(Method::Svd), None)?;
        let svd_exact = {
            let spec = PreserveSpec {
                method: Method::Svd,
                svd_mode: SvdScoreMode::Exact,
                ..Default::default()
            };
            score_layer(name, w, &spec, None)?
        };
        let awq = score_layer(name, w, &spec_of(Method::Awq), Some(&calib))?;
        let spqr = score_layer(name, w, &spec_of(Method::Spqr), Some(&calib))?;
        for &k in &budgets {
            let s_svd = select_topk(&svd, k);
            let i_awq = iou(&s_svd, &select_topk(&awq, k));
            let i_spqr = iou(&s_svd, &select_topk(&spqr, k));
            let i_exact = iou(&s_svd, &select_topk(&svd_exact, k));
            println!(
                "{:<22} {:>6}  {:>8.3} {:>8.3} {:>10.3}",
                name, k, i_awq, i_spqr, i_exact
            );
        }
    }
    println!(
        "\nreading: high vs-SpQR + low vs-AWQ = the paper's claim that \
         principal structure proxies Hessian sensitivity, not activation \
         magnitude. rsvd/exact near 1.0 justifies the O(r·d²) fast path."
    );
    Ok(())
}
