//! Fig. 2 deep-dive: *is SVD finding the same weights as the Hessian?*
//!
//! Reproduces the paper's overlap analysis per layer (not just the
//! aggregate): IoU of the SVD-selected index set vs AWQ and SpQR at each
//! budget, plus the exact-vs-randomized SVD agreement ablation
//! (DESIGN.md §5). Heuristics are `Scorer` trait objects from the registry
//! — swap any name below for e.g. `"hybrid"` to analyze a new heuristic.
//!
//! ```sh
//! cargo run --release --offline --example overlap_analysis [task]
//! ```

use svdquant::calib::CalibStats;
use svdquant::coordinator::Artifacts;
use svdquant::model::Engine;
use svdquant::saliency::{
    iou, resolve_scorer, select_topk, ScoreCtx, Scorer, SvdScoreMode, SvdScorer,
};

fn main() -> anyhow::Result<()> {
    let task = std::env::args().nth(1).unwrap_or_else(|| "mrpc".to_string());
    let art = Artifacts::open("artifacts")?;
    let ckpt = art.checkpoint(&task)?;
    let calib_data = art.dataset(&task, "calib")?;
    let engine = Engine::new(art.model_cfg, ckpt)?;
    let calib = CalibStats::collect(&engine, &calib_data, art.calib_samples(), 16)?;
    let ckpt = engine.params();
    let ctx = ScoreCtx::with_calib(&calib);

    let sparams = art.scorer_params();
    let svd = resolve_scorer("svd", &sparams)?;
    let svd_exact = SvdScorer::new(art.svd_rank(), SvdScoreMode::Exact);
    let awq = resolve_scorer("awq", &sparams)?;
    let spqr = resolve_scorer("spqr", &sparams)?;

    let budgets = [16usize, 256, 4096];
    println!("per-layer IoU of SVD selections vs baselines ({task})\n");
    println!("{:<22} {:>6}  {:>8} {:>8} {:>10}", "layer", "k", "vs AWQ", "vs SpQR", "rsvd/exact");
    let names = art.model_cfg.quantizable_names();
    for name in &names {
        let w = ckpt.get(name)?;
        let s_svd = svd.score(name, w, &ctx)?;
        let s_exact = svd_exact.score(name, w, &ctx)?;
        let s_awq = awq.score(name, w, &ctx)?;
        let s_spqr = spqr.score(name, w, &ctx)?;
        for &k in &budgets {
            let sel = select_topk(&s_svd, k);
            let i_awq = iou(&sel, &select_topk(&s_awq, k));
            let i_spqr = iou(&sel, &select_topk(&s_spqr, k));
            let i_exact = iou(&sel, &select_topk(&s_exact, k));
            println!(
                "{:<22} {:>6}  {:>8.3} {:>8.3} {:>10.3}",
                name, k, i_awq, i_spqr, i_exact
            );
        }
    }
    println!(
        "\nreading: high vs-SpQR + low vs-AWQ = the paper's claim that \
         principal structure proxies Hessian sensitivity, not activation \
         magnitude. rsvd/exact near 1.0 justifies the O(r·d²) fast path."
    );
    Ok(())
}
