//! Quickstart: the paper's scheme in ~30 lines of API.
//!
//! Loads a trained checkpoint, quantizes it with the data-free SVD
//! heuristic at k=256, and measures accuracy recovery against the FP32
//! ceiling and the unprotected Q4 floor — all through the AOT-compiled
//! XLA executable (python never runs). The two budgets share one
//! `QuantizePipeline`, so the expensive score maps are computed once.
//!
//! Run after `make artifacts`:
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```

use svdquant::coordinator::{Artifacts, QuantizePipeline};
use svdquant::eval::eval_pjrt;
use svdquant::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let art = Artifacts::open("artifacts")?;
    let task = "mrpc";
    println!("model: {} params", art.model_cfg.param_count());

    let ckpt = art.checkpoint(task)?;
    let dev = art.dataset(task, "dev")?;
    let rt = Runtime::cpu()?;
    let exe = art.compile_model(&rt, task, false)?;

    // FP32 ceiling
    let fp32 = eval_pjrt(&exe, &art.model_cfg, &ckpt, &dev)?.accuracy();

    // one pipeline, default scorer = the paper's SVD (zero calibration data)
    let mut pipe = QuantizePipeline::for_checkpoint(&art.model_cfg, &ckpt).build()?;

    // unprotected 4-bit floor (k = 0)
    let (floor_params, _) = pipe.run_with_budget(0)?;
    let floor = eval_pjrt(&exe, &art.model_cfg, &floor_params, &dev)?.accuracy();

    // the paper's method: preserve the top-256 principal-structure weights
    // per layer in FP32 — score maps are reused from the k=0 pass above
    let (qparams, sels) = pipe.run_with_budget(256)?;
    let svd = eval_pjrt(&exe, &art.model_cfg, &qparams, &dev)?.accuracy();

    let protected: usize = sels.values().map(|s| s.k()).sum();
    println!("\n{task}: {} samples", dev.len());
    println!("  FP32 ceiling      {fp32:.4}");
    println!("  Q4 floor (k=0)    {floor:.4}");
    println!("  SVD k=256         {svd:.4}   ({protected} weights protected)");
    let denom = (fp32 - floor).max(1e-9);
    println!(
        "  recovery          {:.1}% of the FP32–Q4 gap",
        100.0 * (svd - floor) / denom
    );
    Ok(())
}
