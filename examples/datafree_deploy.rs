//! The privacy scenario that motivates the paper (§I, §VII): quantize a
//! model **without ever seeing data**, deploy it as packed b-bit + sparse
//! FP32, and serve a live request trace with dynamic batching.
//!
//! End-to-end driver over the full stack: data-free SVD selection (L3
//! linalg) → packed QuantizedModel → batching server → latency/throughput/
//! accuracy report. Compare against an AWQ deployment which *requires*
//! calibration access.
//!
//! ```sh
//! cargo run --release --offline --example datafree_deploy
//! ```

use std::time::Duration;

use svdquant::coordinator::server::{serve_trace, ServerConfig};
use svdquant::coordinator::{Artifacts, QuantizePipeline};
use svdquant::data::TraceGenerator;
use svdquant::model::QuantizedModel;
use svdquant::quant::QuantConfig;
use svdquant::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let art = Artifacts::open("artifacts")?;
    let task = "qnli";
    let ckpt = art.checkpoint(task)?;
    let dev = art.dataset(task, "dev")?;

    // --- data-free quantization: only the weights are touched ------------
    // default scorer = SVD, no .calib(..) anywhere: the pipeline enforces
    // at build time that the scorer really needs no data
    let qcfg = QuantConfig::default();
    let t = std::time::Instant::now();
    let sels = {
        let mut pipe = QuantizePipeline::for_checkpoint(&art.model_cfg, &ckpt)
            .budget(1024)
            .quant(qcfg)
            .build()?;
        pipe.select(1024)?
    };
    let qm = QuantizedModel::build(art.model_cfg, ckpt, &qcfg, &sels)?;
    let quant_s = t.elapsed().as_secs_f64();
    let (q, d) = qm.quantized_bytes();
    println!("quantized in {quant_s:.2}s with ZERO calibration samples");
    println!(
        "weights: {} -> {} ({:.2}x compression)",
        human_bytes(d),
        human_bytes(q),
        d as f64 / q as f64
    );

    // --- serve a bursty trace on a 2-worker pool -------------------------
    for (name, gen) in [
        ("poisson 40 req/s", TraceGenerator::poisson(40.0)),
        ("bursty  40 req/s", TraceGenerator::bursty(40.0, 0.25, 8)),
    ] {
        let trace = gen.generate(160, dev.len(), 0xD431);
        let cfg = ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            queue_cap: 256,
            workers: 2,
            deadline: Some(Duration::from_millis(250)),
            clock: svdquant::util::clock::Clock::wall(),
            ..ServerConfig::default()
        };
        let s = serve_trace(&qm, &dev, &trace, &cfg)?;
        println!(
            "\n[{name}] {} reqs ({} shed, {} expired) in {:.2}s -> {:.1} req/s | \
             p50 {:.1} ms, p95 {:.1} ms, p99 {:.1} ms | mean batch {:.1} | acc {:.4}",
            s.completions, s.shed, s.expired, s.wall_s, s.throughput_rps, s.p50_ms,
            s.p95_ms, s.p99_ms, s.mean_batch, s.accuracy
        );
    }
    println!(
        "\n(an AWQ/SpQR deployment would additionally require {} calibration \
         sequences of production data before any of this could run)",
        art.calib_samples()
    );
    Ok(())
}
