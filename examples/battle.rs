//! "The Battle" (paper §IV): SVD vs AWQ vs SpQR vs Random on one task,
//! across the full protection-budget grid — a single-task version of the
//! sweep, printed as the paper's table layout.
//!
//! ```sh
//! cargo run --release --offline --example battle [task]
//! ```

use svdquant::calib::CalibStats;
use svdquant::coordinator::sweep::{run_sweep, SweepConfig};
use svdquant::coordinator::Artifacts;
use svdquant::model::Engine;
use svdquant::report;
use svdquant::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let task = std::env::args().nth(1).unwrap_or_else(|| "rte".to_string());
    let art = Artifacts::open("artifacts")?;
    anyhow::ensure!(art.tasks().contains(&task), "unknown task {task}");
    let rt = Runtime::cpu()?;

    // show what the data-aware baselines consume and SVD doesn't
    let ckpt = art.checkpoint(&task)?;
    let calib_data = art.dataset(&task, "calib")?;
    let engine = Engine::new(art.model_cfg, ckpt)?;
    let stats = CalibStats::collect(&engine, &calib_data, art.calib_samples(), 16)?;
    let tokens: usize = stats.layers.values().map(|l| l.rows).sum::<usize>()
        / stats.layers.len().max(1);
    println!(
        "calibration for AWQ/SpQR: {} sequences (~{} tokens/layer) — \
         the SVD method uses none of it\n",
        stats.samples, tokens
    );

    let out = std::path::PathBuf::from("results");
    let mut cfg = SweepConfig::paper_defaults(&art, &out);
    cfg.tasks = vec![task.clone()];
    cfg.methods = ["random", "awq", "spqr", "svd"].iter().map(|m| m.to_string()).collect();
    let res = run_sweep(&art, &rt, &cfg)?;

    println!("\n{}", report::accuracy_table(&res, &task, &cfg.budgets));
    println!("{}", report::fig1_panel(&res, &task, &cfg.budgets));
    println!("{}", report::fig2_chart(&res));
    Ok(())
}
